"""L2 graph builders: the entire RL iteration as one fused jax function.

Each graph has signature ``f32[N] -> f32[N]`` (or small fixed extras) over
the unified flat data store (see layout.py), so the rust coordinator chains
device buffers with zero host transfer — the paper's "entire RL workflow on
the GPU with a unified in-place data store".

Graph set per environment (DESIGN.md section 2):
  init        f32[1] seed          -> f32[N] packed state
  train_iter  f32[N]               -> f32[N]   T-step roll-out + A2C update
  rollout     f32[N]               -> f32[N]   roll-out only (throughput)
  metrics     f32[N]               -> f32[M]   scalar telemetry
  get_params  f32[N]               -> f32[P]
  set_params  f32[N], f32[P]       -> f32[N]
  avg2        f32[P], f32[P]       -> f32[P]   multi-device param averaging
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from . import algo, models
from .envs.base import EnvSpec
from .layout import Layout

METRIC_NAMES = (
    "iter", "env_steps", "ep_return_ema", "ep_len_ema", "episodes_done",
    "pi_loss", "v_loss", "entropy", "grad_norm", "reward_mean",
    "value_mean", "adam_t",
)


@dataclasses.dataclass
class TrainConfig:
    """Hyperparameters baked into the lowered graphs."""

    n_envs: int = 1024
    t: int = 32                # roll-out length per iteration
    hidden: int = 64
    gamma: float = 0.99
    lam: float = 0.95          # GAE lambda
    use_gae: bool = True
    lr: float = 1e-2
    vf_coef: float = 0.25
    ent_coef: float = 0.005
    max_grad_norm: float = 2.0
    ema: float = 0.9           # episodic stat smoothing
    use_pallas: bool = True
    block: int = 0             # 0 = auto (whole batch in one grid block)


def _block(cfg: TrainConfig):
    return cfg.block if cfg.block > 0 else cfg.n_envs


def _wrap_key(bits_u32: jnp.ndarray):
    return jax.random.wrap_key_data(bits_u32, impl="threefry2x32")


def _key_bits(key) -> jnp.ndarray:
    return jax.random.key_data(key).astype(jnp.uint32)


def build_layout(env: EnvSpec, cfg: TrainConfig) -> Layout:
    """Field layout of the unified store for a single-policy env."""
    n = cfg.n_envs
    lo = Layout()
    for name, (tail, dtype) in env.field_defs.items():
        lo.add(f"env.{name}", (n,) + tuple(tail), dtype, group="env")
    lo.add("ep_steps", (n,), "f32", group="episode")
    lo.add("ep_return", (n,), "f32", group="episode")
    lo.add("rng", (2,), "u32", group="rng")
    continuous = env.act_type == "continuous"
    shapes = models.param_shapes(env.obs_dim, cfg.hidden, env.n_actions,
                                 continuous)
    for pname in list(models.PARAM_ORDER) + (
            ["log_std"] if continuous else []):
        lo.add(f"param.{pname}", shapes[pname], "f32", group="params")
    for pname in list(models.PARAM_ORDER) + (
            ["log_std"] if continuous else []):
        lo.add(f"adam_m.{pname}", shapes[pname], "f32", group="opt")
    for pname in list(models.PARAM_ORDER) + (
            ["log_std"] if continuous else []):
        lo.add(f"adam_v.{pname}", shapes[pname], "f32", group="opt")
    lo.add("adam_t", (), "f32", group="opt")
    for s in ("iter", "env_steps", "ep_return_ema", "ep_len_ema",
              "episodes_done", "pi_loss", "v_loss", "entropy", "grad_norm",
              "reward_mean", "value_mean"):
        lo.add(f"stat.{s}", (), "f32", group="stats")
    return lo


def _split_fields(env: EnvSpec, vals: Dict[str, jnp.ndarray]):
    envf = {k[len("env."):]: v for k, v in vals.items()
            if k.startswith("env.")}
    params = {k[len("param."):]: v for k, v in vals.items()
              if k.startswith("param.")}
    return envf, params


def _policy_sample(env: EnvSpec, cfg: TrainConfig, params, obs, key):
    """Sample an action + return value estimate (inference path)."""
    out, value = models.forward(params, obs, use_pallas=cfg.use_pallas,
                                block=_block(cfg))
    if env.act_type == "discrete":
        action = algo.categorical_sample(key, out)
        return action, value
    mean = out
    action = algo.gaussian_sample(key, mean, params["log_std"])
    return env.act_scale * jnp.tanh(action), value


def _rollout_scan(env: EnvSpec, cfg: TrainConfig, vals, collect: bool):
    """T-step roll-out with auto-reset; returns (vals', trajectory or None,
    final obs, episode-stat accumulators)."""
    envf, params = _split_fields(env, vals)
    key = _wrap_key(vals["rng"])

    def body(carry, _):
        envf, ep_steps, ep_ret, key, acc = carry
        obs = env.obs(envf)
        key, k_act, k_reset = jax.random.split(key, 3)
        action, value = _policy_sample(env, cfg, params, obs, k_act)
        envf2, rew, term_f = env.step(envf, action, cfg.use_pallas)
        ep_steps2 = ep_steps + 1.0
        trunc_f = (ep_steps2 >= float(env.max_steps)).astype(jnp.float32)
        done = jnp.clip(term_f + trunc_f, 0.0, 1.0)
        ep_ret2 = ep_ret + rew
        # episode completion accounting (before the reset wipes it)
        sum_ret, sum_len, n_done = acc
        acc2 = (sum_ret + jnp.sum(done * ep_ret2),
                sum_len + jnp.sum(done * ep_steps2),
                n_done + jnp.sum(done))
        envf3 = env.reset_where(envf2, k_reset, done)
        ep_steps3 = ep_steps2 * (1.0 - done)
        ep_ret3 = ep_ret2 * (1.0 - done)
        ys = (obs, action, rew, done, value) if collect else None
        return (envf3, ep_steps3, ep_ret3, key, acc2), ys

    acc0 = (jnp.zeros(()), jnp.zeros(()), jnp.zeros(()))
    carry0 = (envf, vals["ep_steps"], vals["ep_return"], key, acc0)
    (envf, ep_steps, ep_ret, key, acc), traj = lax.scan(
        body, carry0, None, length=cfg.t)

    vals = dict(vals)
    for k, v in envf.items():
        vals[f"env.{k}"] = v
    vals["ep_steps"] = ep_steps
    vals["ep_return"] = ep_ret
    vals["rng"] = _key_bits(key)
    final_obs = env.obs(envf)
    return vals, traj, final_obs, acc


def _update_episode_stats(cfg: TrainConfig, vals, acc):
    sum_ret, sum_len, n_done = acc
    has = (n_done > 0).astype(jnp.float32)
    mean_ret = sum_ret / jnp.maximum(n_done, 1.0)
    mean_len = sum_len / jnp.maximum(n_done, 1.0)
    first = (vals["stat.episodes_done"] == 0).astype(jnp.float32)
    # seed the EMA with the first observed batch mean, then smooth
    blend = lambda old, new: (first * new
                              + (1 - first) * (cfg.ema * old
                                               + (1 - cfg.ema) * new))
    vals["stat.ep_return_ema"] = jnp.where(
        has > 0, blend(vals["stat.ep_return_ema"], mean_ret),
        vals["stat.ep_return_ema"])
    vals["stat.ep_len_ema"] = jnp.where(
        has > 0, blend(vals["stat.ep_len_ema"], mean_len),
        vals["stat.ep_len_ema"])
    vals["stat.episodes_done"] = vals["stat.episodes_done"] + n_done
    return vals


def build_graphs(env: EnvSpec, cfg: TrainConfig):
    """Returns (layout, dict graph_name -> (callable, example_args))."""
    lo = build_layout(env, cfg)
    n = cfg.n_envs
    continuous = env.act_type == "continuous"
    pnames = list(models.PARAM_ORDER) + (["log_std"] if continuous else [])
    p_off, p_size = lo.group_span("params")

    # ----------------------------------------------------------------- init
    def init(seed: jnp.ndarray) -> jnp.ndarray:
        key = jax.random.PRNGKey(seed[0].astype(jnp.int32))
        k_env, k_par, k_run = jax.random.split(key, 3)
        envf = env.init(k_env, n)
        params = models.init_params(k_par, env.obs_dim, cfg.hidden,
                                    env.n_actions, continuous)
        opt = algo.adam_init(params)
        vals: Dict[str, jnp.ndarray] = {}
        for k, v in envf.items():
            vals[f"env.{k}"] = v
        vals["ep_steps"] = jnp.zeros((n,), jnp.float32)
        vals["ep_return"] = jnp.zeros((n,), jnp.float32)
        vals["rng"] = _key_bits(k_run)
        for pn in pnames:
            vals[f"param.{pn}"] = params[pn]
            vals[f"adam_m.{pn}"] = opt["m"][pn]
            vals[f"adam_v.{pn}"] = opt["v"][pn]
        vals["adam_t"] = opt["t"]
        for f in lo.group("stats"):
            vals[f.name] = jnp.zeros((), jnp.float32)
        return lo.pack(vals)

    # ----------------------------------------------------------- train_iter
    def train_iter(flat: jnp.ndarray) -> jnp.ndarray:
        vals = lo.unpack(flat)
        vals, traj, final_obs, acc = _rollout_scan(env, cfg, vals,
                                                   collect=True)
        obs_t, act_t, rew_t, done_t, val_t = traj

        _, params = _split_fields(env, vals)
        _, boot = models.forward(params, final_obs,
                                 use_pallas=cfg.use_pallas, block=_block(cfg))
        boot = lax.stop_gradient(boot)
        if cfg.use_gae:
            adv, rets = algo.gae_advantages(rew_t, done_t, val_t, boot,
                                            cfg.gamma, cfg.lam)
        else:
            rets = algo.nstep_returns(rew_t, done_t, boot, cfg.gamma)
            adv = rets - val_t
        adv = (adv - jnp.mean(adv)) / (jnp.std(adv) + 1e-8)
        obs_flat = obs_t.reshape((-1, env.obs_dim))
        act_flat = (act_t.reshape((-1,)) if env.act_type == "discrete"
                    else act_t.reshape((-1, env.n_actions)))
        rets_flat = rets.reshape((-1,))
        adv_flat = adv.reshape((-1,))

        def loss_fn(params):
            # training recompute in plain jnp (autodiff path)
            out, vpred = models.forward(params, obs_flat, use_pallas=False)
            if env.act_type == "discrete":
                logp = algo.categorical_logp(out, act_flat)
                ent = algo.categorical_entropy(out)
            else:
                # invert the tanh squash for the stored env action
                pre = jnp.arctanh(jnp.clip(act_flat / env.act_scale,
                                           -0.999999, 0.999999))
                logp = algo.gaussian_logp(out, params["log_std"], pre)
                ent = jnp.broadcast_to(
                    algo.gaussian_entropy(params["log_std"]), logp.shape)
            loss, (pi_l, v_l, e) = algo.a2c_loss_terms(
                logp, ent, vpred, rets_flat, adv_flat,
                cfg.vf_coef, cfg.ent_coef)
            return loss, (pi_l, v_l, e, vpred)

        params = {pn: vals[f"param.{pn}"] for pn in pnames}
        grads, (pi_l, v_l, e, vpred) = jax.grad(
            loss_fn, has_aux=True)(params)
        grads, gnorm = algo.clip_by_global_norm(grads, cfg.max_grad_norm)
        m = {pn: vals[f"adam_m.{pn}"] for pn in pnames}
        v = {pn: vals[f"adam_v.{pn}"] for pn in pnames}
        params, m, v, t = algo.adam_update(params, grads, m, v,
                                           vals["adam_t"], cfg.lr)
        for pn in pnames:
            vals[f"param.{pn}"] = params[pn]
            vals[f"adam_m.{pn}"] = m[pn]
            vals[f"adam_v.{pn}"] = v[pn]
        vals["adam_t"] = t

        vals = _update_episode_stats(cfg, vals, acc)
        vals["stat.iter"] = vals["stat.iter"] + 1.0
        vals["stat.env_steps"] = vals["stat.env_steps"] + float(cfg.t * n)
        vals["stat.pi_loss"] = pi_l
        vals["stat.v_loss"] = v_l
        vals["stat.entropy"] = e
        vals["stat.grad_norm"] = gnorm
        vals["stat.reward_mean"] = jnp.mean(rew_t)
        vals["stat.value_mean"] = jnp.mean(vpred)
        return lo.pack(vals)

    # -------------------------------------------------------------- rollout
    def rollout(flat: jnp.ndarray) -> jnp.ndarray:
        vals = lo.unpack(flat)
        vals, _, _, acc = _rollout_scan(env, cfg, vals, collect=False)
        vals = _update_episode_stats(cfg, vals, acc)
        vals["stat.env_steps"] = vals["stat.env_steps"] + float(cfg.t * n)
        return lo.pack(vals)

    # -------------------------------------------------------------- metrics
    def metrics(flat: jnp.ndarray) -> jnp.ndarray:
        vals = lo.unpack(flat)
        stats = [vals[f"stat.{s}"] for s in METRIC_NAMES if s != "adam_t"]
        return jnp.stack(stats + [vals["adam_t"]])

    # ------------------------------------------------------- params plumbing
    def get_params(flat: jnp.ndarray) -> jnp.ndarray:
        return lax.slice(flat, (p_off,), (p_off + p_size,))

    def set_params(flat: jnp.ndarray, pvec: jnp.ndarray) -> jnp.ndarray:
        return lax.dynamic_update_slice(flat, pvec, (p_off,))

    def avg2(p1: jnp.ndarray, p2: jnp.ndarray) -> jnp.ndarray:
        return 0.5 * (p1 + p2)

    f32 = jnp.float32
    state_spec = jax.ShapeDtypeStruct((lo.total,), f32)
    pvec_spec = jax.ShapeDtypeStruct((p_size,), f32)
    graphs = {
        "init": (init, (jax.ShapeDtypeStruct((1,), f32),)),
        "train_iter": (train_iter, (state_spec,)),
        "rollout": (rollout, (state_spec,)),
        "metrics": (metrics, (state_spec,)),
        "get_params": (get_params, (state_spec,)),
        "set_params": (set_params, (state_spec, pvec_spec)),
        "avg2": (avg2, (pvec_spec, pvec_spec)),
    }
    return lo, graphs
