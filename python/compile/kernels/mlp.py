"""L1 Pallas kernel: fused actor-critic MLP forward (inference hot path).

The roll-out loop evaluates the policy for every env every step; this kernel
fuses both hidden layers and both heads into a single pass so intermediate
activations never leave VMEM (on TPU; on this CPU testbed the structure is
preserved through ``interpret=True``).

TPU sizing rationale (DESIGN.md section 5 / section 6): block B envs x H=64
hidden keeps all four weight matrices plus a (B, H) activation tile well
under 16 MiB VMEM for B <= 2048; matmul shapes (B,obs)x(obs,H) and
(B,H)x(H,H) feed the MXU with the batch axis as rows.  Training recomputes
the forward pass in plain jnp under ``jax.grad`` — only inference runs the
fused kernel.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .steps import _env_block


def _mlp_kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref,
                wp_ref, bp_ref, wv_ref, bv_ref, logits_ref, value_ref):
    h1 = jnp.tanh(x_ref[...] @ w1_ref[...] + b1_ref[...])
    h2 = jnp.tanh(h1 @ w2_ref[...] + b2_ref[...])
    logits_ref[...] = h2 @ wp_ref[...] + bp_ref[...]
    value_ref[...] = (h2 @ wv_ref[...] + bv_ref[...])[:, 0]


def mlp_forward(x: jnp.ndarray, w1, b1, w2, b2, wp, bp, wv, bv,
                block: int | None = None) -> tuple:
    """Fused policy+value forward.  x (N, obs) -> (logits (N,A), value (N,)).

    Weights are broadcast to every grid block (the paper's "reference, not
    copy" of the policy model shared by all env blocks).
    """
    n, obs = x.shape
    h1 = w1.shape[1]
    h2 = w2.shape[1]
    a = wp.shape[1]
    b = _env_block(n, block)
    full = lambda shape: pl.BlockSpec(shape, lambda i: tuple(0 for _ in shape))
    logits, value = pl.pallas_call(
        _mlp_kernel,
        grid=(n // b,),
        in_specs=[
            pl.BlockSpec((b, obs), lambda i: (i, 0)),
            full((obs, h1)), full((h1,)),
            full((h1, h2)), full((h2,)),
            full((h2, a)), full((a,)),
            full((h2, 1)), full((1,)),
        ],
        out_specs=[
            pl.BlockSpec((b, a), lambda i: (i, 0)),
            pl.BlockSpec((b,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, a), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=True,
    )(x, w1, b1, w2, b2, wp, bp, wv, bv)
    return logits, value
