"""L1 Pallas kernels: batched environment-step physics.

Hardware adaptation of the paper's CUDA layout (DESIGN.md section 5): the
paper runs one environment per GPU *block* and one agent per *thread*; here
the Pallas grid tiles the leading env axis, each program instance advancing
a BLOCK of environments held in VMEM, and the agent axis is vectorized on
the VPU lanes inside the block.

All kernels are deterministic (sampling noise is injected by the caller),
lower through ``interpret=True`` (CPU PJRT cannot execute Mosaic
custom-calls) and are oracle-checked against :mod:`.ref` by pytest.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

DEFAULT_BLOCK = 256


def _env_block(n_envs: int, block: int | None) -> int:
    """Largest divisor of ``n_envs`` not exceeding the requested block."""
    b = min(block or DEFAULT_BLOCK, n_envs)
    while n_envs % b != 0:
        b -= 1
    return max(b, 1)


# --------------------------------------------------------------------------
# CartPole
# --------------------------------------------------------------------------
def _cartpole_kernel(s_ref, a_ref, ns_ref, r_ref, d_ref):
    nxt, rew, term = ref.cartpole_step_ref(s_ref[...], a_ref[...])
    ns_ref[...] = nxt
    r_ref[...] = rew
    d_ref[...] = term.astype(jnp.float32)


def cartpole_step(state: jnp.ndarray, action: jnp.ndarray,
                  block: int | None = None) -> tuple:
    """Pallas CartPole step.  state (N,4) f32, action (N,) i32.

    Returns (next_state (N,4), reward (N,), done_f (N,) f32 0/1).
    """
    n = state.shape[0]
    b = _env_block(n, block)
    grid = (n // b,)
    return pl.pallas_call(
        _cartpole_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, 4), lambda i: (i, 0)),
            pl.BlockSpec((b,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((b, 4), lambda i: (i, 0)),
            pl.BlockSpec((b,), lambda i: (i,)),
            pl.BlockSpec((b,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, 4), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=True,
    )(state, action)


# --------------------------------------------------------------------------
# Acrobot
# --------------------------------------------------------------------------
def _acrobot_kernel(s_ref, a_ref, ns_ref, r_ref, d_ref):
    nxt, rew, term = ref.acrobot_step_ref(s_ref[...], a_ref[...])
    ns_ref[...] = nxt
    r_ref[...] = rew
    d_ref[...] = term.astype(jnp.float32)


def acrobot_step(state: jnp.ndarray, action: jnp.ndarray,
                 block: int | None = None) -> tuple:
    """Pallas Acrobot RK4 step.  state (N,4), action (N,) i32 in {0,1,2}."""
    n = state.shape[0]
    b = _env_block(n, block)
    return pl.pallas_call(
        _acrobot_kernel,
        grid=(n // b,),
        in_specs=[
            pl.BlockSpec((b, 4), lambda i: (i, 0)),
            pl.BlockSpec((b,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((b, 4), lambda i: (i, 0)),
            pl.BlockSpec((b,), lambda i: (i,)),
            pl.BlockSpec((b,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, 4), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=True,
    )(state, action)


# --------------------------------------------------------------------------
# Pendulum (continuous action)
# --------------------------------------------------------------------------
def _pendulum_kernel(s_ref, a_ref, ns_ref, r_ref, d_ref):
    nxt, rew, term = ref.pendulum_step_ref(s_ref[...], a_ref[...])
    ns_ref[...] = nxt
    r_ref[...] = rew
    d_ref[...] = term.astype(jnp.float32)


def pendulum_step(state: jnp.ndarray, action: jnp.ndarray,
                  block: int | None = None) -> tuple:
    """Pallas Pendulum step.  state (N,2), action (N,) f32 torque."""
    n = state.shape[0]
    b = _env_block(n, block)
    return pl.pallas_call(
        _pendulum_kernel,
        grid=(n // b,),
        in_specs=[
            pl.BlockSpec((b, 2), lambda i: (i, 0)),
            pl.BlockSpec((b,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((b, 2), lambda i: (i, 0)),
            pl.BlockSpec((b,), lambda i: (i,)),
            pl.BlockSpec((b,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, 2), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=True,
    )(state, action)


# --------------------------------------------------------------------------
# COVID economy (multi-agent: 51 governors + federal, inter-agent reduction
# happens inside the block = the paper's cross-thread interaction)
# --------------------------------------------------------------------------
def _covid_kernel(sir_ref, econ_ref, calib_ref, ga_ref, fa_ref,
                  nsir_ref, necon_ref, gr_ref, fr_ref):
    sir2, econ2, gr, fr = ref.covid_step_ref(
        sir_ref[...], econ_ref[...], calib_ref[...], ga_ref[...], fa_ref[...])
    nsir_ref[...] = sir2
    necon_ref[...] = econ2
    gr_ref[...] = gr
    fr_ref[...] = fr


def covid_step(sir: jnp.ndarray, econ: jnp.ndarray, calib: jnp.ndarray,
               gov_action: jnp.ndarray, fed_action: jnp.ndarray,
               block: int | None = None) -> tuple:
    """Pallas COVID-economy step.

    sir (N,S,3), econ (N,S), calib (S,3) shared, gov_action (N,S) i32,
    fed_action (N,) i32 -> (sir', econ', gov_reward (N,S), fed_reward (N,)).
    """
    n, s = sir.shape[0], sir.shape[1]
    b = _env_block(n, block or 64)
    return pl.pallas_call(
        _covid_kernel,
        grid=(n // b,),
        in_specs=[
            pl.BlockSpec((b, s, 3), lambda i: (i, 0, 0)),
            pl.BlockSpec((b, s), lambda i: (i, 0)),
            pl.BlockSpec((s, 3), lambda i: (0, 0)),
            pl.BlockSpec((b, s), lambda i: (i, 0)),
            pl.BlockSpec((b,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((b, s, 3), lambda i: (i, 0, 0)),
            pl.BlockSpec((b, s), lambda i: (i, 0)),
            pl.BlockSpec((b, s), lambda i: (i, 0)),
            pl.BlockSpec((b,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, s, 3), jnp.float32),
            jax.ShapeDtypeStruct((n, s), jnp.float32),
            jax.ShapeDtypeStruct((n, s), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=True,
    )(sir, econ, calib, gov_action, fed_action)


# --------------------------------------------------------------------------
# Catalysis (Mueller-Brown PES walk)
# --------------------------------------------------------------------------
def _catalysis_kernel(bump_amp, pos_ref, pert_ref, a_ref,
                      npos_ref, r_ref, d_ref):
    nxt, rew, term = ref.catalysis_step_ref(
        pos_ref[...], pert_ref[...], a_ref[...], bump_amp)
    npos_ref[...] = nxt
    r_ref[...] = rew
    d_ref[...] = term.astype(jnp.float32)


def catalysis_step(pos: jnp.ndarray, perturb: jnp.ndarray,
                   action: jnp.ndarray, bump_amp: float = 0.0,
                   block: int | None = None) -> tuple:
    """Pallas PES step.  pos (N,2), perturb (N,), action (N,) i32 0..7."""
    n = pos.shape[0]
    b = _env_block(n, block)
    return pl.pallas_call(
        functools.partial(_catalysis_kernel, bump_amp),
        grid=(n // b,),
        in_specs=[
            pl.BlockSpec((b, 2), lambda i: (i, 0)),
            pl.BlockSpec((b,), lambda i: (i,)),
            pl.BlockSpec((b,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((b, 2), lambda i: (i, 0)),
            pl.BlockSpec((b,), lambda i: (i,)),
            pl.BlockSpec((b,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, 2), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=True,
    )(pos, perturb, action)


def mb_energy(pos: jnp.ndarray, perturb: jnp.ndarray,
              bump_amp: float = 0.0, block: int | None = None) -> jnp.ndarray:
    """Pallas batched Mueller-Brown energy evaluation.  pos (N,2)."""
    n = pos.shape[0]
    b = _env_block(n, block)

    def kern(pos_ref, pert_ref, e_ref):
        e_ref[...] = ref.mb_energy_ref(pos_ref[...], pert_ref[...], bump_amp)

    return pl.pallas_call(
        kern,
        grid=(n // b,),
        in_specs=[
            pl.BlockSpec((b, 2), lambda i: (i, 0)),
            pl.BlockSpec((b,), lambda i: (i,)),
        ],
        out_specs=[pl.BlockSpec((b,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((n,), jnp.float32)],
        interpret=True,
    )(pos, perturb)[0]
