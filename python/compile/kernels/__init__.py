"""L1 Pallas kernels + pure-jnp oracles (ref)."""
from . import ref  # noqa: F401
from .steps import (  # noqa: F401
    acrobot_step, cartpole_step, catalysis_step, covid_step, mb_energy,
    pendulum_step,
)
from .mlp import mlp_forward  # noqa: F401
