"""Pure-jnp oracles for every Pallas kernel.

These are the CORE correctness signal: each L1 kernel in this package must
match its oracle to float32 round-off under pytest + hypothesis sweeps
(``python/tests/test_kernels.py``).  They are also the ``use_pallas=False``
fallback path used in A/B perf comparisons (EXPERIMENTS.md §Perf).

All oracles are deterministic, batched over a leading env axis, and free of
PRNG use — stochasticity (action sampling, reset noise) is injected by the
caller so kernels stay bit-reproducible.
"""

from __future__ import annotations

import jax.numpy as jnp

# --------------------------------------------------------------------------
# CartPole-v1 (gym classic_control, euler integrator)
# --------------------------------------------------------------------------
CARTPOLE = dict(
    gravity=9.8, masscart=1.0, masspole=0.1, length=0.5, force_mag=10.0,
    dt=0.02, x_threshold=2.4, theta_threshold=12 * 2 * jnp.pi / 360,
    max_steps=500,
)


def cartpole_step_ref(state: jnp.ndarray, action: jnp.ndarray) -> tuple:
    """One Euler step of CartPole.

    state:  (N, 4)  [x, x_dot, theta, theta_dot]
    action: (N,)    int {0, 1}
    returns (next_state (N,4), reward (N,), terminated (N,) bool)
    """
    c = CARTPOLE
    x, x_dot, th, th_dot = state[:, 0], state[:, 1], state[:, 2], state[:, 3]
    force = jnp.where(action == 1, c["force_mag"], -c["force_mag"])
    costh, sinth = jnp.cos(th), jnp.sin(th)
    total_mass = c["masscart"] + c["masspole"]
    polemass_length = c["masspole"] * c["length"]
    temp = (force + polemass_length * th_dot**2 * sinth) / total_mass
    thacc = (c["gravity"] * sinth - costh * temp) / (
        c["length"] * (4.0 / 3.0 - c["masspole"] * costh**2 / total_mass))
    xacc = temp - polemass_length * thacc * costh / total_mass
    x = x + c["dt"] * x_dot
    x_dot = x_dot + c["dt"] * xacc
    th = th + c["dt"] * th_dot
    th_dot = th_dot + c["dt"] * thacc
    nxt = jnp.stack([x, x_dot, th, th_dot], axis=1)
    terminated = ((jnp.abs(x) > c["x_threshold"])
                  | (jnp.abs(th) > c["theta_threshold"]))
    reward = jnp.ones_like(x)
    return nxt, reward, terminated


# --------------------------------------------------------------------------
# Acrobot-v1 (gym classic_control, single RK4 step, "book" dynamics)
# --------------------------------------------------------------------------
ACROBOT = dict(
    dt=0.2, l1=1.0, lc1=0.5, lc2=0.5, m1=1.0, m2=1.0, i1=1.0, i2=1.0,
    g=9.8, max_vel1=4 * jnp.pi, max_vel2=9 * jnp.pi, max_steps=500,
)


def _acrobot_dsdt(s: jnp.ndarray, torque: jnp.ndarray) -> jnp.ndarray:
    """Acrobot ODE.  s: (N, 4) [th1, th2, dth1, dth2], torque: (N,)."""
    a = ACROBOT
    th1, th2, dth1, dth2 = s[:, 0], s[:, 1], s[:, 2], s[:, 3]
    m1, m2, l1, lc1, lc2, i1, i2, g = (a["m1"], a["m2"], a["l1"], a["lc1"],
                                       a["lc2"], a["i1"], a["i2"], a["g"])
    d1 = (m1 * lc1**2 + m2 * (l1**2 + lc2**2 + 2 * l1 * lc2 * jnp.cos(th2))
          + i1 + i2)
    d2 = m2 * (lc2**2 + l1 * lc2 * jnp.cos(th2)) + i2
    phi2 = m2 * lc2 * g * jnp.cos(th1 + th2 - jnp.pi / 2.0)
    phi1 = (-m2 * l1 * lc2 * dth2**2 * jnp.sin(th2)
            - 2 * m2 * l1 * lc2 * dth2 * dth1 * jnp.sin(th2)
            + (m1 * lc1 + m2 * l1) * g * jnp.cos(th1 - jnp.pi / 2.0) + phi2)
    ddth2 = ((torque + d2 / d1 * phi1
              - m2 * l1 * lc2 * dth1**2 * jnp.sin(th2) - phi2)
             / (m2 * lc2**2 + i2 - d2**2 / d1))
    ddth1 = -(d2 * ddth2 + phi1) / d1
    return jnp.stack([dth1, dth2, ddth1, ddth2], axis=1)


def _wrap(x, lo, hi):
    return lo + jnp.mod(x - lo, hi - lo)


def acrobot_step_ref(state: jnp.ndarray, action: jnp.ndarray) -> tuple:
    """One RK4 step of Acrobot.

    state:  (N, 4)  [th1, th2, dth1, dth2]
    action: (N,)    int {0,1,2} -> torque {-1,0,+1}
    returns (next_state, reward (N,), terminated (N,))
    """
    a = ACROBOT
    torque = action.astype(jnp.float32) - 1.0
    dt = a["dt"]
    k1 = _acrobot_dsdt(state, torque)
    k2 = _acrobot_dsdt(state + dt / 2.0 * k1, torque)
    k3 = _acrobot_dsdt(state + dt / 2.0 * k2, torque)
    k4 = _acrobot_dsdt(state + dt * k3, torque)
    ns = state + dt / 6.0 * (k1 + 2 * k2 + 2 * k3 + k4)
    th1 = _wrap(ns[:, 0], -jnp.pi, jnp.pi)
    th2 = _wrap(ns[:, 1], -jnp.pi, jnp.pi)
    dth1 = jnp.clip(ns[:, 2], -a["max_vel1"], a["max_vel1"])
    dth2 = jnp.clip(ns[:, 3], -a["max_vel2"], a["max_vel2"])
    nxt = jnp.stack([th1, th2, dth1, dth2], axis=1)
    terminated = (-jnp.cos(th1) - jnp.cos(th2 + th1)) > 1.0
    reward = jnp.where(terminated, 0.0, -1.0)
    return nxt, reward, terminated


def acrobot_obs_ref(state: jnp.ndarray) -> jnp.ndarray:
    """(N,4) internal state -> (N,6) gym observation."""
    th1, th2, dth1, dth2 = state[:, 0], state[:, 1], state[:, 2], state[:, 3]
    return jnp.stack([jnp.cos(th1), jnp.sin(th1), jnp.cos(th2),
                      jnp.sin(th2), dth1, dth2], axis=1)


# --------------------------------------------------------------------------
# Pendulum-v1 (continuous torque)
# --------------------------------------------------------------------------
PENDULUM = dict(dt=0.05, g=10.0, m=1.0, l=1.0, max_speed=8.0,
                max_torque=2.0, max_steps=200)


def pendulum_step_ref(state: jnp.ndarray, action: jnp.ndarray) -> tuple:
    """One step of Pendulum.

    state:  (N, 2)  [theta, theta_dot]
    action: (N,)    continuous torque (clipped to +-max_torque)
    returns (next_state, reward (N,), terminated (N,) always False)
    """
    p = PENDULUM
    th, thdot = state[:, 0], state[:, 1]
    u = jnp.clip(action, -p["max_torque"], p["max_torque"])
    th_norm = _wrap(th, -jnp.pi, jnp.pi)
    cost = th_norm**2 + 0.1 * thdot**2 + 0.001 * u**2
    newthdot = thdot + (3.0 * p["g"] / (2.0 * p["l"]) * jnp.sin(th)
                        + 3.0 / (p["m"] * p["l"] ** 2) * u) * p["dt"]
    newthdot = jnp.clip(newthdot, -p["max_speed"], p["max_speed"])
    newth = th + newthdot * p["dt"]
    nxt = jnp.stack([newth, newthdot], axis=1)
    return nxt, -cost, jnp.zeros_like(cost, dtype=bool)


def pendulum_obs_ref(state: jnp.ndarray) -> jnp.ndarray:
    th, thdot = state[:, 0], state[:, 1]
    return jnp.stack([jnp.cos(th), jnp.sin(th), thdot], axis=1)


# --------------------------------------------------------------------------
# COVID-19 two-level economy (51 governors + 1 federal agent)
# --------------------------------------------------------------------------
COVID = dict(
    n_states=51, n_agents=52, n_actions=10, max_steps=52,
    gamma_rec=0.1,        # recovery rate / step
    mu_mort=0.012,        # infection fatality per step among infected
    beta_damp=0.085,      # stringency damping of transmission per level
    econ_damp=0.065,      # stringency damping of economic output per level
    subsidy_boost=0.045,  # federal subsidy restoring output per level
    subsidy_cost=0.02,    # federal budget cost per subsidy level
    death_weight=60.0,    # health term scale in rewards
    mix=0.04,             # inter-state infection mixing fraction
)


def covid_step_ref(sir: jnp.ndarray, econ: jnp.ndarray,
                   calib: jnp.ndarray, gov_action: jnp.ndarray,
                   fed_action: jnp.ndarray) -> tuple:
    """One week of the two-level COVID economy.

    sir:        (N, S, 3)  [susceptible, infected, dead] fractions per state
    econ:       (N, S)     economic output index per state
    calib:      (S, 3)     per-state calibration [beta0, q0, health_weight]
    gov_action: (N, S)     int stringency level 0..9
    fed_action: (N,)       int subsidy level 0..9
    returns (sir', econ', gov_reward (N,S), fed_reward (N,))
    """
    c = COVID
    s, i, d = sir[..., 0], sir[..., 1], sir[..., 2]
    beta0 = calib[:, 0][None, :]
    q0 = calib[:, 1][None, :]
    hw = calib[:, 2][None, :]
    stringency = gov_action.astype(jnp.float32)
    subsidy = fed_action.astype(jnp.float32)[:, None]

    # transmission: local + national mixing, damped by stringency
    i_nat = jnp.mean(i, axis=1, keepdims=True)
    beta = beta0 * (1.0 - c["beta_damp"] * stringency)
    new_inf = jnp.clip(beta * s * ((1 - c["mix"]) * i + c["mix"] * i_nat),
                       0.0, s)
    new_rec = c["gamma_rec"] * i
    new_dead = c["mu_mort"] * i
    s2 = s - new_inf
    i2 = jnp.clip(i + new_inf - new_rec - new_dead, 0.0, 1.0)
    d2 = d + new_dead

    # economy: output damped by stringency and sickness, restored by subsidy
    open_frac = 1.0 - c["econ_damp"] * stringency
    q2 = q0 * open_frac * (1.0 - 0.5 * i2) + c["subsidy_boost"] * subsidy
    econ2 = 0.5 * econ + 0.5 * q2  # smoothed output index

    gov_reward = q2 - hw * c["death_weight"] * new_dead
    fed_reward = (jnp.mean(gov_reward, axis=1)
                  - c["subsidy_cost"] * subsidy[:, 0])
    sir2 = jnp.stack([s2, i2, d2], axis=-1)
    return sir2, econ2, gov_reward, fed_reward


# --------------------------------------------------------------------------
# Catalysis: extended Mueller-Brown potential energy surface
# --------------------------------------------------------------------------
# The standard reaction-path benchmark surface: 3 minima (reactant,
# intermediate, product) and 2 saddle points.  Stands in for the paper's
# DFT-derived Fe(111) NH2+H landscape (see DESIGN.md section 7).
MB_A = (-200.0, -100.0, -170.0, 15.0)
MB_a = (-1.0, -1.0, -6.5, 0.7)
MB_b = (0.0, 0.0, 11.0, 0.6)
MB_c = (-10.0, -10.0, -6.5, 0.7)
MB_x0 = (1.0, 0.0, -0.5, -1.0)
MB_y0 = (0.0, 0.5, 1.5, 1.0)

# well-known stationary points
MB_MIN_REACTANT = (0.6235, 0.0280)    # shallow minimum ("adsorbed NH2 + H")
MB_MIN_PRODUCT = (-0.5582, 1.4417)    # deep minimum ("NH3")
MB_MIN_INTERMEDIATE = (-0.0500, 0.4667)

CATALYSIS = dict(
    max_steps=200, step_len=0.09, n_actions=8,
    product_radius=0.35, product_bonus=30.0, step_penalty=0.1,
    energy_scale=30.0,   # reward shaping divisor
    x_lo=-1.8, x_hi=1.3, y_lo=-0.6, y_hi=2.2,
    lh_bump_amp=40.0,    # co-adsorbate repulsion (Langmuir-Hinshelwood)
    lh_bump_x=0.35, lh_bump_y=0.85, lh_bump_w=0.12,
)


def mb_energy_ref(pos: jnp.ndarray, perturb: jnp.ndarray,
                  bump_amp: float = 0.0) -> jnp.ndarray:
    """Extended Mueller-Brown energy.

    pos:     (..., 2) positions
    perturb: (...,)   per-env multiplicative perturbation of well depths
                      ("local variations" of the environment, paper app. B)
    bump_amp: static co-adsorbate Gaussian (LH geometry) amplitude
    returns  (...,) energy
    """
    x, y = pos[..., 0], pos[..., 1]
    e = jnp.zeros_like(x)
    for A, a, b, c_, x0, y0 in zip(MB_A, MB_a, MB_b, MB_c, MB_x0, MB_y0):
        dx, dy = x - x0, y - y0
        e = e + A * jnp.exp(a * dx * dx + b * dx * dy + c_ * dy * dy)
    e = e * (1.0 + perturb)
    if bump_amp:
        cat = CATALYSIS
        dx = x - cat["lh_bump_x"]
        dy = y - cat["lh_bump_y"]
        e = e + bump_amp * jnp.exp(-(dx * dx + dy * dy)
                                   / (2.0 * cat["lh_bump_w"]))
    return e


def catalysis_step_ref(pos: jnp.ndarray, perturb: jnp.ndarray,
                       action: jnp.ndarray, bump_amp: float) -> tuple:
    """One move of the H-atom actor on the PES.

    pos:     (N, 2) current positions
    perturb: (N,)   per-env well-depth perturbation
    action:  (N,)   int 0..7 compass direction
    returns (next_pos, reward (N,), terminated (N,))
    """
    cat = CATALYSIS
    ang = action.astype(jnp.float32) * (2.0 * jnp.pi / cat["n_actions"])
    delta = jnp.stack([jnp.cos(ang), jnp.sin(ang)], axis=1) * cat["step_len"]
    new = pos + delta
    new = jnp.stack([
        jnp.clip(new[:, 0], cat["x_lo"], cat["x_hi"]),
        jnp.clip(new[:, 1], cat["y_lo"], cat["y_hi"]),
    ], axis=1)
    e_old = mb_energy_ref(pos, perturb, bump_amp)
    e_new = mb_energy_ref(new, perturb, bump_amp)
    dx = new[:, 0] - MB_MIN_PRODUCT[0]
    dy = new[:, 1] - MB_MIN_PRODUCT[1]
    in_product = (dx * dx + dy * dy) < cat["product_radius"] ** 2
    reward = (-(e_new - e_old) / cat["energy_scale"] - cat["step_penalty"]
              + jnp.where(in_product, cat["product_bonus"], 0.0))
    return new, reward, in_product


# --------------------------------------------------------------------------
# Ecosystem management: generalized Lotka-Volterra community
# --------------------------------------------------------------------------
ECOSYSTEM = dict(
    n_species=16, n_actions=17, max_steps=200, dt=0.05,
    x_max=6.0,            # population cap
    x_ext=0.05,           # extinction threshold -> episode collapse
    harvest_frac=0.2,     # fraction removed per harvest action
    alive_bonus=0.05,     # per-step bonus scaled by surviving fraction
    collapse_penalty=25.0,
)


def _lv_dsdt(x: jnp.ndarray, r: jnp.ndarray, a: jnp.ndarray) -> jnp.ndarray:
    """Generalized Lotka-Volterra derivative.

    x: (N, S) populations, r: (N, S) per-episode rates,
    a: (S, S) interaction matrix (effect of species j on i).
    """
    return x * (r + x @ a.T)


def ecosystem_step_ref(x: jnp.ndarray, r: jnp.ndarray, a: jnp.ndarray,
                       price: jnp.ndarray, action: jnp.ndarray) -> tuple:
    """One managed step: optional harvest, one RK4 LV step, clamp.

    x:      (N, S)  populations
    r:      (N, S)  per-episode growth/mortality rates (constant)
    a:      (S, S)  interaction matrix (fixed calibration)
    price:  (S,)    market price per harvested unit
    action: (N,)    int 0 = wait, 1..S = harvest species a-1
    returns (next_x, reward (N,), collapsed (N,))
    """
    e = ECOSYSTEM
    sel = jnp.arange(e["n_species"])[None, :] == (action[:, None] - 1)
    h = jnp.where(sel, x * e["harvest_frac"], 0.0)
    harvest = (h * price[None, :]).sum(axis=1)
    x = x - h
    dt = e["dt"]
    k1 = _lv_dsdt(x, r, a)
    k2 = _lv_dsdt(x + dt / 2.0 * k1, r, a)
    k3 = _lv_dsdt(x + dt / 2.0 * k2, r, a)
    k4 = _lv_dsdt(x + dt * k3, r, a)
    x = x + dt / 6.0 * (k1 + 2 * k2 + 2 * k3 + k4)
    x = jnp.clip(x, 0.0, e["x_max"])
    alive = (x >= e["x_ext"]).sum(axis=1)
    collapsed = alive < e["n_species"]
    reward = (harvest + e["alive_bonus"] * alive / e["n_species"]
              - jnp.where(collapsed, e["collapse_penalty"], 0.0))
    return x, reward, collapsed


# --------------------------------------------------------------------------
# Bioreactor: 1-D reaction-diffusion nutrient/biomass control
# --------------------------------------------------------------------------
BIOREACTOR = dict(
    nx=32, n_actions=8, max_steps=200, dt=0.1, substeps=2,
    d_n=0.25, d_b=0.05,   # nutrient / biomass diffusion
    mu_max=1.2, k_s=0.5,  # Monod growth kinetics
    yield_inv=2.0, decay=0.08,
    n_max=4.0, b_max=5.0,
    feed_cells=(3, 11, 19, 27), feed_rates=(0.25, 0.75),
    feed_cost=0.05, prod_w=4.0,
    b_ext=1e-3, washout_penalty=10.0,
)


def _reflect_lap(u: jnp.ndarray) -> jnp.ndarray:
    """1-D Laplacian with reflective boundaries.  u: (N, NX)."""
    left = jnp.concatenate([u[:, :1], u[:, :-1]], axis=1)
    right = jnp.concatenate([u[:, 1:], u[:, -1:]], axis=1)
    return left - 2.0 * u + right


def bioreactor_step_ref(nu: jnp.ndarray, b: jnp.ndarray,
                        action: jnp.ndarray) -> tuple:
    """One feed + SUBSTEPS explicit Euler substeps.

    nu:     (N, NX) nutrient field
    b:      (N, NX) biomass field
    action: (N,)    int: port = a // 2 (of feed_cells), rate = a % 2
    returns (nu', b', reward (N,), washout (N,))
    """
    c = BIOREACTOR
    ports = jnp.array(c["feed_cells"])[action // 2]
    rate = jnp.array(c["feed_rates"])[action % 2]
    feed = (jnp.arange(c["nx"])[None, :] == ports[:, None]) * rate[:, None]
    nu = jnp.minimum(nu + feed, c["n_max"])
    g = jnp.zeros_like(nu)
    for _ in range(c["substeps"]):
        g = c["mu_max"] * nu / (c["k_s"] + nu) * b
        nu2 = nu + c["dt"] * (c["d_n"] * _reflect_lap(nu)
                              - c["yield_inv"] * g)
        b2 = b + c["dt"] * (c["d_b"] * _reflect_lap(b) + g
                            - c["decay"] * b)
        nu = jnp.clip(nu2, 0.0, c["n_max"])
        b = jnp.clip(b2, 0.0, c["b_max"])
    prod_mean = g.mean(axis=1)
    washout = b.mean(axis=1) < c["b_ext"]
    reward = (c["prod_w"] * prod_mean - c["feed_cost"] * rate
              - jnp.where(washout, c["washout_penalty"], 0.0))
    return nu, b, reward, washout


# --------------------------------------------------------------------------
# Fused actor-critic MLP forward (policy inference hot path)
# --------------------------------------------------------------------------
def mlp_forward_ref(x: jnp.ndarray, w1, b1, w2, b2, wp, bp, wv, bv) -> tuple:
    """2-hidden-layer tanh MLP with policy + value heads.

    x: (N, obs)  ->  logits (N, A), value (N,)
    """
    h1 = jnp.tanh(x @ w1 + b1)
    h2 = jnp.tanh(h1 @ w2 + b2)
    logits = h2 @ wp + bp
    value = (h2 @ wv + bv)[:, 0]
    return logits, value
