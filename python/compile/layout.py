"""Unified data-store layout: the WarpSci in-place GPU store, flattened.

Every persistent piece of RL state (environment physics, PRNG key, policy
parameters, Adam moments, episode statistics) lives in ONE flat f32 device
buffer.  Each L2 graph has signature ``f32[N] -> f32[N]`` so the rust
coordinator can chain ``execute_b`` calls with zero host transfer (PJRT via
xla_extension 0.5.1 returns multi-output executables as a single
un-splittable tuple buffer; a single flat array sidesteps that entirely).

Integer fields (PRNG key bits, step counters) are stored bit-exactly via
``lax.bitcast_convert_type`` so no information is lost in the f32 container.

The :class:`Layout` doubles as the manifest generator: the rust side reads
``manifest.json`` to get named (offset, shape, dtype) views into the store.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Iterable, List, Tuple

import jax
import jax.numpy as jnp
from jax import lax

# dtypes representable inside the f32 container.
_DTYPES = ("f32", "i32", "u32")


@dataclasses.dataclass(frozen=True)
class Field:
    """A named view into the flat store."""

    name: str
    shape: Tuple[int, ...]
    dtype: str  # one of _DTYPES
    offset: int

    @property
    def size(self) -> int:
        return int(math.prod(self.shape)) if self.shape else 1

    def to_manifest(self) -> dict:
        return {
            "name": self.name,
            "shape": list(self.shape),
            "dtype": self.dtype,
            "offset": self.offset,
            "size": self.size,
        }


class Layout:
    """Ordered registry of fields inside the flat f32 state vector."""

    def __init__(self) -> None:
        self._fields: Dict[str, Field] = {}
        self._total = 0
        self._groups: Dict[str, List[str]] = {}

    # ------------------------------------------------------------------ build
    def add(self, name: str, shape: Iterable[int], dtype: str = "f32",
            group: str = "state") -> Field:
        if name in self._fields:
            raise ValueError(f"duplicate field {name!r}")
        if dtype not in _DTYPES:
            raise ValueError(f"dtype {dtype!r} not in {_DTYPES}")
        shape = tuple(int(s) for s in shape)
        f = Field(name=name, shape=shape, dtype=dtype, offset=self._total)
        self._fields[name] = f
        self._total += f.size
        self._groups.setdefault(group, []).append(name)
        return f

    @property
    def total(self) -> int:
        return self._total

    def fields(self) -> List[Field]:
        return list(self._fields.values())

    def field(self, name: str) -> Field:
        return self._fields[name]

    def group(self, name: str) -> List[Field]:
        return [self._fields[n] for n in self._groups.get(name, [])]

    def group_span(self, name: str) -> Tuple[int, int]:
        """(offset, size) of a group; fields in a group must be contiguous."""
        fs = self.group(name)
        if not fs:
            return (0, 0)
        off = fs[0].offset
        end = off
        for f in fs:
            if f.offset != end:
                raise ValueError(f"group {name!r} is not contiguous at {f.name}")
            end = f.offset + f.size
        return (off, end - off)

    # ------------------------------------------------------------- pack/unpack
    def pack(self, values: Dict[str, jnp.ndarray]) -> jnp.ndarray:
        """Pack a dict of arrays into the flat f32 vector (order = layout)."""
        parts = []
        for f in self._fields.values():
            v = jnp.asarray(values[f.name])
            if v.shape != f.shape:
                raise ValueError(
                    f"field {f.name}: shape {v.shape} != layout {f.shape}")
            flat = v.reshape((-1,)) if f.shape else v.reshape((1,))
            if f.dtype == "f32":
                flat = flat.astype(jnp.float32)
            elif f.dtype == "i32":
                flat = lax.bitcast_convert_type(
                    flat.astype(jnp.int32), jnp.float32)
            elif f.dtype == "u32":
                flat = lax.bitcast_convert_type(
                    flat.astype(jnp.uint32), jnp.float32)
            parts.append(flat)
        if not parts:
            return jnp.zeros((0,), jnp.float32)
        return jnp.concatenate(parts)

    def unpack(self, flat: jnp.ndarray) -> Dict[str, jnp.ndarray]:
        """Static-sliced views (bit-cast back to declared dtypes)."""
        out: Dict[str, jnp.ndarray] = {}
        for f in self._fields.values():
            seg = lax.slice(flat, (f.offset,), (f.offset + f.size,))
            if f.dtype == "i32":
                seg = lax.bitcast_convert_type(seg, jnp.int32)
            elif f.dtype == "u32":
                seg = lax.bitcast_convert_type(seg, jnp.uint32)
            out[f.name] = seg.reshape(f.shape)
        return out

    def repack(self, flat: jnp.ndarray,
               updates: Dict[str, jnp.ndarray]) -> jnp.ndarray:
        """Rebuild the flat vector replacing the given fields."""
        vals = self.unpack(flat)
        for k, v in updates.items():
            if k not in vals:
                raise KeyError(k)
            vals[k] = v
        return self.pack(vals)

    # ---------------------------------------------------------------- manifest
    def to_manifest(self) -> dict:
        return {
            "total": self._total,
            "fields": [f.to_manifest() for f in self._fields.values()],
            "groups": {g: list(ns) for g, ns in self._groups.items()},
        }


def tree_size(tree) -> int:
    """Total element count of a pytree of arrays."""
    return sum(int(math.prod(x.shape)) for x in jax.tree_util.tree_leaves(tree))


def flatten_tree(tree) -> jnp.ndarray:
    """Flatten a pytree of f32 arrays into one vector (canonical leaf order)."""
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.concatenate([x.reshape((-1,)).astype(jnp.float32)
                            for x in leaves])


def unflatten_like(tree, flat: jnp.ndarray):
    """Inverse of :func:`flatten_tree` given a template pytree."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    out, off = [], 0
    for leaf in leaves:
        n = int(math.prod(leaf.shape)) if leaf.shape else 1
        out.append(lax.slice(flat, (off,), (off + n,)).reshape(leaf.shape))
        off += n
    return jax.tree_util.tree_unflatten(treedef, out)
