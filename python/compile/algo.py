"""Actor-critic RL algorithm pieces: distributions, returns, A2C loss, Adam.

Everything is written from scratch in jnp (no optax/flax in the build
environment) and unit-tested against numpy references in
``python/tests/test_algo.py``.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

# --------------------------------------------------------------------------
# distributions
# --------------------------------------------------------------------------
def categorical_sample(key, logits: jnp.ndarray) -> jnp.ndarray:
    """Gumbel-max sample.  logits (..., A) -> (...,) int32."""
    return jax.random.categorical(key, logits).astype(jnp.int32)


def categorical_logp(logits: jnp.ndarray, action: jnp.ndarray) -> jnp.ndarray:
    logz = jax.nn.log_softmax(logits)
    return jnp.take_along_axis(logz, action[..., None].astype(jnp.int32),
                               axis=-1)[..., 0]


def categorical_entropy(logits: jnp.ndarray) -> jnp.ndarray:
    logz = jax.nn.log_softmax(logits)
    p = jnp.exp(logz)
    return -jnp.sum(p * logz, axis=-1)


_LOG_2PI = float(jnp.log(2.0 * jnp.pi))


def gaussian_sample(key, mean: jnp.ndarray, log_std: jnp.ndarray):
    std = jnp.exp(log_std)
    return mean + std * jax.random.normal(key, mean.shape)


def gaussian_logp(mean, log_std, action) -> jnp.ndarray:
    std = jnp.exp(log_std)
    z = (action - mean) / std
    return jnp.sum(-0.5 * z * z - log_std - 0.5 * _LOG_2PI, axis=-1)


def gaussian_entropy(log_std) -> jnp.ndarray:
    return jnp.sum(log_std + 0.5 * (_LOG_2PI + 1.0))


# --------------------------------------------------------------------------
# return estimators.  rewards/dones/values: (T, N); bootstrap: (N,)
# --------------------------------------------------------------------------
def nstep_returns(rewards, dones, bootstrap, gamma: float) -> jnp.ndarray:
    """Discounted n-step returns R_t = r_t + gamma * (1 - d_t) * R_{t+1}."""
    def body(carry, xs):
        r, d = xs
        ret = r + gamma * (1.0 - d) * carry
        return ret, ret
    _, rets = jax.lax.scan(body, bootstrap, (rewards, dones), reverse=True)
    return rets


def gae_advantages(rewards, dones, values, bootstrap,
                   gamma: float, lam: float) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """GAE(lambda).  values: (T, N) V(s_t).  Returns (advantages, returns)."""
    next_values = jnp.concatenate([values[1:], bootstrap[None]], axis=0)
    deltas = rewards + gamma * (1.0 - dones) * next_values - values

    def body(carry, xs):
        delta, d = xs
        adv = delta + gamma * lam * (1.0 - d) * carry
        return adv, adv
    _, advs = jax.lax.scan(body, jnp.zeros_like(bootstrap),
                           (deltas, dones), reverse=True)
    return advs, advs + values


# --------------------------------------------------------------------------
# A2C loss (forward recompute happens in the caller's closure)
# --------------------------------------------------------------------------
def a2c_loss_terms(logp, entropy, values_pred, returns, advantages,
                   vf_coef: float, ent_coef: float):
    """Scalar loss + components.  All inputs flattened (T*N,)."""
    pi_loss = -jnp.mean(logp * jax.lax.stop_gradient(advantages))
    v_loss = jnp.mean((values_pred - jax.lax.stop_gradient(returns)) ** 2)
    ent = jnp.mean(entropy)
    loss = pi_loss + vf_coef * v_loss - ent_coef * ent
    return loss, (pi_loss, v_loss, ent)


# --------------------------------------------------------------------------
# Adam with global-norm clipping (from scratch)
# --------------------------------------------------------------------------
def adam_init(params: Dict[str, jnp.ndarray]):
    zeros = {k: jnp.zeros_like(v) for k, v in params.items()}
    return {"m": zeros, "v": {k: jnp.zeros_like(v) for k, v in params.items()},
            "t": jnp.zeros((), jnp.float32)}


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(x * x) for x in leaves))


def clip_by_global_norm(grads, max_norm: float):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-8))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), gn


def adam_update(params, grads, m, v, t, lr: float,
                b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8):
    """One Adam step.  Returns (params', m', v', t')."""
    t2 = t + 1.0
    bc1 = 1.0 - b1 ** t2
    bc2 = 1.0 - b2 ** t2
    new_p, new_m, new_v = {}, {}, {}
    for k in params:
        g = grads[k]
        new_m[k] = b1 * m[k] + (1.0 - b1) * g
        new_v[k] = b2 * v[k] + (1.0 - b2) * g * g
        mh = new_m[k] / bc1
        vh = new_v[k] / bc2
        new_p[k] = params[k] - lr * mh / (jnp.sqrt(vh) + eps)
    return new_p, new_m, new_v, t2
