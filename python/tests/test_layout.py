"""Unit + property tests for the unified data-store layout."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.layout import Layout, flatten_tree, tree_size, unflatten_like


def test_offsets_are_contiguous():
    lo = Layout()
    lo.add("a", (4, 2))
    lo.add("b", (3,), "i32")
    lo.add("c", ())
    assert lo.field("a").offset == 0
    assert lo.field("b").offset == 8
    assert lo.field("c").offset == 11
    assert lo.total == 12


def test_duplicate_field_rejected():
    lo = Layout()
    lo.add("a", (1,))
    with pytest.raises(ValueError):
        lo.add("a", (2,))


def test_bad_dtype_rejected():
    lo = Layout()
    with pytest.raises(ValueError):
        lo.add("a", (1,), "f64")


def test_pack_unpack_roundtrip_f32():
    lo = Layout()
    lo.add("x", (2, 3))
    lo.add("y", (5,))
    vals = {"x": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "y": jnp.linspace(-1, 1, 5)}
    flat = lo.pack(vals)
    out = lo.unpack(flat)
    np.testing.assert_array_equal(np.asarray(out["x"]), np.asarray(vals["x"]))
    np.testing.assert_array_equal(np.asarray(out["y"]),
                                  np.asarray(vals["y"], np.float32))


def test_bitcast_roundtrip_exact_u32():
    lo = Layout()
    lo.add("key", (2,), "u32")
    # extreme bit patterns incl. ones that are NaN as floats
    vals = {"key": jnp.array([0xFFFFFFFF, 0x7FC00001], dtype=jnp.uint32)}
    out = lo.unpack(lo.pack(vals))
    np.testing.assert_array_equal(np.asarray(out["key"]),
                                  np.asarray(vals["key"]))


def test_bitcast_roundtrip_exact_i32():
    lo = Layout()
    lo.add("n", (4,), "i32")
    vals = {"n": jnp.array([-2**31, -1, 0, 2**31 - 1], dtype=jnp.int32)}
    out = lo.unpack(lo.pack(vals))
    np.testing.assert_array_equal(np.asarray(out["n"]), np.asarray(vals["n"]))


def test_repack_replaces_only_given_fields():
    lo = Layout()
    lo.add("a", (3,))
    lo.add("b", (3,))
    flat = lo.pack({"a": jnp.ones(3), "b": jnp.zeros(3)})
    flat2 = lo.repack(flat, {"b": jnp.full((3,), 7.0)})
    out = lo.unpack(flat2)
    np.testing.assert_array_equal(np.asarray(out["a"]), np.ones(3, np.float32))
    np.testing.assert_array_equal(np.asarray(out["b"]),
                                  np.full(3, 7.0, np.float32))


def test_group_span_contiguous():
    lo = Layout()
    lo.add("a", (3,), group="g1")
    lo.add("p1", (4,), group="params")
    lo.add("p2", (2, 2), group="params")
    lo.add("z", (1,), group="g2")
    off, size = lo.group_span("params")
    assert (off, size) == (3, 8)


def test_group_span_detects_gap():
    lo = Layout()
    lo.add("p1", (4,), group="params")
    lo.add("gap", (1,), group="other")
    lo.add("p2", (4,), group="params")
    with pytest.raises(ValueError):
        lo.group_span("params")


def test_manifest_structure():
    lo = Layout()
    lo.add("a", (2,), "u32", group="rng")
    m = lo.to_manifest()
    assert m["total"] == 2
    assert m["fields"][0] == {"name": "a", "shape": [2], "dtype": "u32",
                              "offset": 0, "size": 2}
    assert m["groups"] == {"rng": ["a"]}


@settings(max_examples=25, deadline=None)
@given(st.lists(st.tuples(st.integers(1, 6), st.integers(1, 5),
                          st.sampled_from(["f32", "i32", "u32"])),
                min_size=1, max_size=6))
def test_prop_roundtrip_random_layouts(fields):
    lo = Layout()
    rng = np.random.default_rng(0)
    vals = {}
    for idx, (d0, d1, dt) in enumerate(fields):
        name = f"f{idx}"
        lo.add(name, (d0, d1), dt)
        if dt == "f32":
            vals[name] = jnp.asarray(
                rng.standard_normal((d0, d1)), jnp.float32)
        elif dt == "i32":
            vals[name] = jnp.asarray(
                rng.integers(-2**31, 2**31 - 1, (d0, d1)), jnp.int32)
        else:
            vals[name] = jnp.asarray(
                rng.integers(0, 2**32 - 1, (d0, d1)), jnp.uint32)
    out = lo.unpack(lo.pack(vals))
    for k, v in vals.items():
        np.testing.assert_array_equal(np.asarray(out[k]), np.asarray(v))


def test_flatten_unflatten_tree():
    tree = {"a": jnp.ones((2, 2)), "b": jnp.arange(3, dtype=jnp.float32)}
    flat = flatten_tree(tree)
    assert flat.shape == (7,)
    assert tree_size(tree) == 7
    out = unflatten_like(tree, flat)
    np.testing.assert_array_equal(np.asarray(out["a"]), np.ones((2, 2)))
    np.testing.assert_array_equal(np.asarray(out["b"]),
                                  np.arange(3, dtype=np.float32))
