"""From-scratch RL algorithm pieces vs numpy references."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import algo

settings.register_profile("algo", max_examples=20, deadline=None)
settings.load_profile("algo")


def _np_nstep(rewards, dones, bootstrap, gamma):
    T, N = rewards.shape
    out = np.zeros_like(rewards)
    nxt = bootstrap.copy()
    for t in reversed(range(T)):
        nxt = rewards[t] + gamma * (1.0 - dones[t]) * nxt
        out[t] = nxt
    return out


def _np_gae(rewards, dones, values, bootstrap, gamma, lam):
    T, N = rewards.shape
    adv = np.zeros_like(rewards)
    next_v = bootstrap.copy()
    gae = np.zeros(N, np.float32)
    for t in reversed(range(T)):
        delta = rewards[t] + gamma * (1 - dones[t]) * next_v - values[t]
        gae = delta + gamma * lam * (1 - dones[t]) * gae
        adv[t] = gae
        next_v = values[t]
    return adv, adv + values


@given(st.integers(1, 12), st.integers(1, 7), st.integers(0, 2**31 - 1),
       st.floats(0.5, 0.999))
def test_nstep_returns_match_numpy(t, n, seed, gamma):
    rng = np.random.default_rng(seed)
    r = rng.standard_normal((t, n)).astype(np.float32)
    d = (rng.random((t, n)) < 0.2).astype(np.float32)
    boot = rng.standard_normal(n).astype(np.float32)
    got = np.asarray(algo.nstep_returns(jnp.asarray(r), jnp.asarray(d),
                                        jnp.asarray(boot), gamma))
    np.testing.assert_allclose(got, _np_nstep(r, d, boot, gamma),
                               rtol=1e-5, atol=1e-5)


@given(st.integers(1, 12), st.integers(1, 7), st.integers(0, 2**31 - 1),
       st.floats(0.5, 0.999), st.floats(0.0, 1.0))
def test_gae_matches_numpy(t, n, seed, gamma, lam):
    rng = np.random.default_rng(seed)
    r = rng.standard_normal((t, n)).astype(np.float32)
    d = (rng.random((t, n)) < 0.2).astype(np.float32)
    v = rng.standard_normal((t, n)).astype(np.float32)
    boot = rng.standard_normal(n).astype(np.float32)
    adv, rets = algo.gae_advantages(jnp.asarray(r), jnp.asarray(d),
                                    jnp.asarray(v), jnp.asarray(boot),
                                    gamma, lam)
    adv_np, rets_np = _np_gae(r, d, v, boot, gamma, lam)
    np.testing.assert_allclose(np.asarray(adv), adv_np, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(rets), rets_np, rtol=1e-4,
                               atol=1e-4)


def test_gae_lambda1_equals_nstep_minus_values():
    """GAE(1) advantage == n-step return - V (textbook identity)."""
    rng = np.random.default_rng(3)
    r = rng.standard_normal((8, 5)).astype(np.float32)
    d = (rng.random((8, 5)) < 0.3).astype(np.float32)
    v = rng.standard_normal((8, 5)).astype(np.float32)
    boot = rng.standard_normal(5).astype(np.float32)
    adv, rets = algo.gae_advantages(jnp.asarray(r), jnp.asarray(d),
                                    jnp.asarray(v), jnp.asarray(boot),
                                    0.97, 1.0)
    nstep = algo.nstep_returns(jnp.asarray(r), jnp.asarray(d),
                               jnp.asarray(boot), 0.97)
    np.testing.assert_allclose(np.asarray(adv), np.asarray(nstep - v),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(rets), np.asarray(nstep),
                               rtol=1e-4, atol=1e-4)


def test_categorical_logp_entropy_vs_numpy():
    logits = jnp.asarray([[1.0, 2.0, 0.5], [0.0, 0.0, 0.0]])
    a = jnp.asarray([1, 2], dtype=jnp.int32)
    lp = np.asarray(algo.categorical_logp(logits, a))
    z = np.asarray(logits)
    logz = z - np.log(np.exp(z).sum(-1, keepdims=True))
    np.testing.assert_allclose(lp, logz[[0, 1], [1, 2]], rtol=1e-5)
    ent = np.asarray(algo.categorical_entropy(logits))
    p = np.exp(logz)
    np.testing.assert_allclose(ent, -(p * logz).sum(-1), rtol=1e-5)
    # uniform logits -> entropy log(3)
    np.testing.assert_allclose(ent[1], np.log(3.0), rtol=1e-5)


def test_categorical_sample_distribution():
    key = jax.random.PRNGKey(0)
    logits = jnp.log(jnp.asarray([[0.7, 0.2, 0.1]]))
    logits = jnp.broadcast_to(logits, (20000, 3))
    a = np.asarray(algo.categorical_sample(key, logits))
    freq = np.bincount(a, minlength=3) / a.size
    np.testing.assert_allclose(freq, [0.7, 0.2, 0.1], atol=0.02)


def test_gaussian_logp_entropy():
    mean = jnp.zeros((4, 2))
    log_std = jnp.zeros((2,))
    act = jnp.zeros((4, 2))
    lp = np.asarray(algo.gaussian_logp(mean, log_std, act))
    np.testing.assert_allclose(lp, -np.log(2 * np.pi), rtol=1e-5)
    ent = float(algo.gaussian_entropy(log_std))
    np.testing.assert_allclose(ent, 2 * 0.5 * (np.log(2 * np.pi) + 1),
                               rtol=1e-5)


def test_adam_matches_numpy_reference():
    params = {"w": jnp.asarray([1.0, -2.0]), "b": jnp.asarray([0.5])}
    grads = {"w": jnp.asarray([0.1, -0.2]), "b": jnp.asarray([1.0])}
    m = {k: jnp.zeros_like(v) for k, v in params.items()}
    v = {k: jnp.zeros_like(v_) for k, v_ in params.items()}
    p2, m2, v2, t2 = algo.adam_update(params, grads, m, v,
                                      jnp.zeros(()), lr=0.01)
    # numpy reference, one step from zero moments
    for k in params:
        g = np.asarray(grads[k])
        m_np = 0.1 * g
        v_np = 0.001 * g * g
        mh = m_np / (1 - 0.9)
        vh = v_np / (1 - 0.999)
        p_np = np.asarray(params[k]) - 0.01 * mh / (np.sqrt(vh) + 1e-8)
        np.testing.assert_allclose(np.asarray(p2[k]), p_np, rtol=1e-5)
    assert float(t2) == 1.0


def test_clip_by_global_norm():
    grads = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    clipped, gn = algo.clip_by_global_norm(grads, 1.0)
    assert abs(float(gn) - 5.0) < 1e-5
    total = np.sqrt(sum(float(jnp.sum(x * x))
                        for x in jax.tree_util.tree_leaves(clipped)))
    np.testing.assert_allclose(total, 1.0, rtol=1e-4)
    # under the cap: untouched
    clipped2, _ = algo.clip_by_global_norm(grads, 100.0)
    np.testing.assert_allclose(np.asarray(clipped2["a"]), [3.0], rtol=1e-6)


def test_a2c_loss_gradient_direction():
    """Positive advantage must push the taken action's logit up."""
    logits = jnp.zeros((1, 2))

    def loss(logits):
        lp = algo.categorical_logp(logits, jnp.asarray([0]))
        ent = algo.categorical_entropy(logits)
        l, _ = algo.a2c_loss_terms(lp, ent, jnp.zeros(1), jnp.zeros(1),
                                   jnp.asarray([2.0]), 0.0, 0.0)
        return l
    g = jax.grad(loss)(logits)
    assert float(g[0, 0]) < 0.0  # descending on loss raises logit of action 0
    assert float(g[0, 1]) > 0.0
