"""Pallas kernels vs pure-jnp oracles — the L1 correctness signal.

Hypothesis sweeps batch sizes and block shapes (so the grid tiling itself
is exercised, not just the math) and asserts bit-level/allclose agreement.
"""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import kernels
from compile.kernels import ref
from compile.kernels.steps import _env_block

settings.register_profile("kernels", max_examples=8, deadline=None)
settings.load_profile("kernels")

_N = st.sampled_from([1, 2, 16, 64, 130])
_BLOCK = st.sampled_from([None, 1, 3, 16, 64, 256])


def _key(seed):
    return jax.random.PRNGKey(seed)


# ---------------------------------------------------------------- env_block
@given(st.integers(1, 10000), st.integers(1, 512))
def test_env_block_divides(n, b):
    blk = _env_block(n, b)
    assert 1 <= blk <= n and n % blk == 0 and blk <= max(b, 1)


# ----------------------------------------------------------------- cartpole
@given(_N, _BLOCK, st.integers(0, 2**31 - 1))
def test_cartpole_matches_ref(n, block, seed):
    k1, k2 = jax.random.split(_key(seed))
    s = jax.random.uniform(k1, (n, 4), minval=-2.0, maxval=2.0)
    a = jax.random.randint(k2, (n,), 0, 2).astype(jnp.int32)
    ns, r, d = kernels.cartpole_step(s, a, block=block)
    ns2, r2, d2 = ref.cartpole_step_ref(s, a)
    np.testing.assert_allclose(np.asarray(ns), np.asarray(ns2),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(r), np.asarray(r2))
    np.testing.assert_array_equal(np.asarray(d),
                                  np.asarray(d2, np.float32))


# ------------------------------------------------------------------ acrobot
@given(_N, _BLOCK, st.integers(0, 2**31 - 1))
def test_acrobot_matches_ref(n, block, seed):
    k1, k2 = jax.random.split(_key(seed))
    s = jax.random.uniform(k1, (n, 4), minval=-3.0, maxval=3.0)
    a = jax.random.randint(k2, (n,), 0, 3).astype(jnp.int32)
    ns, r, d = kernels.acrobot_step(s, a, block=block)
    ns2, r2, d2 = ref.acrobot_step_ref(s, a)
    np.testing.assert_allclose(np.asarray(ns), np.asarray(ns2),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(d),
                                  np.asarray(d2, np.float32))


# ----------------------------------------------------------------- pendulum
@given(_N, _BLOCK, st.integers(0, 2**31 - 1))
def test_pendulum_matches_ref(n, block, seed):
    k1, k2 = jax.random.split(_key(seed))
    s = jax.random.uniform(k1, (n, 2), minval=-4.0, maxval=4.0)
    a = jax.random.uniform(k2, (n,), minval=-3.0, maxval=3.0)
    ns, r, d = kernels.pendulum_step(s, a, block=block)
    ns2, r2, d2 = ref.pendulum_step_ref(s, a)
    np.testing.assert_allclose(np.asarray(ns), np.asarray(ns2), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(r), np.asarray(r2), rtol=1e-5)
    assert not np.any(np.asarray(d))


# -------------------------------------------------------------------- covid
@given(st.sampled_from([1, 8, 40]), st.sampled_from([None, 1, 8, 64]),
       st.integers(0, 2**31 - 1))
def test_covid_matches_ref(n, block, seed):
    s = ref.COVID["n_states"]
    ks = jax.random.split(_key(seed), 5)
    i0 = jax.random.uniform(ks[0], (n, s), minval=0.0, maxval=0.2)
    sir = jnp.stack([1.0 - i0, i0, jnp.zeros_like(i0)], axis=-1)
    econ = jax.random.uniform(ks[1], (n, s), minval=0.5, maxval=1.5)
    calib = jnp.stack([
        jax.random.uniform(ks[2], (s,), minval=0.2, maxval=0.5),
        jnp.ones((s,)), jnp.ones((s,))], axis=1)
    ga = jax.random.randint(ks[3], (n, s), 0, 10).astype(jnp.int32)
    fa = jax.random.randint(ks[4], (n,), 0, 10).astype(jnp.int32)
    outs = kernels.covid_step(sir, econ, calib, ga, fa, block=block)
    refs = ref.covid_step_ref(sir, econ, calib, ga, fa)
    for o, r_ in zip(outs, refs):
        np.testing.assert_allclose(np.asarray(o), np.asarray(r_),
                                   rtol=1e-6, atol=1e-7)


# ---------------------------------------------------------------- catalysis
@given(_N, _BLOCK, st.integers(0, 2**31 - 1),
       st.sampled_from([0.0, 40.0]))
def test_catalysis_matches_ref(n, block, seed, bump):
    ks = jax.random.split(_key(seed), 3)
    pos = jax.random.uniform(ks[0], (n, 2), minval=-1.5, maxval=1.2)
    pert = 0.05 * jax.random.normal(ks[1], (n,))
    a = jax.random.randint(ks[2], (n,), 0, 8).astype(jnp.int32)
    ns, r, d = kernels.catalysis_step(pos, pert, a, bump_amp=bump,
                                      block=block)
    ns2, r2, d2 = ref.catalysis_step_ref(pos, pert, a, bump)
    np.testing.assert_allclose(np.asarray(ns), np.asarray(ns2), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(r), np.asarray(r2),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_array_equal(np.asarray(d), np.asarray(d2, np.float32))


@given(_N, st.integers(0, 2**31 - 1))
def test_mb_energy_matches_ref(n, seed):
    ks = jax.random.split(_key(seed), 2)
    pos = jax.random.uniform(ks[0], (n, 2), minval=-1.5, maxval=1.2)
    pert = 0.05 * jax.random.normal(ks[1], (n,))
    e = kernels.mb_energy(pos, pert)
    e2 = ref.mb_energy_ref(pos, pert)
    np.testing.assert_allclose(np.asarray(e), np.asarray(e2),
                               rtol=1e-5, atol=1e-3)


def test_mb_stationary_points():
    """The three catalogued minima must actually be low-energy points."""
    pts = jnp.asarray([ref.MB_MIN_REACTANT, ref.MB_MIN_PRODUCT,
                       ref.MB_MIN_INTERMEDIATE])
    e = ref.mb_energy_ref(pts, jnp.zeros(3))
    # product ("NH3") is the global minimum; the intermediate basin is the
    # shallowest of the three
    assert float(e[1]) < float(e[0]) < float(e[2]) < 0.0
    # gradient is ~0 at each minimum
    g = jax.vmap(jax.grad(lambda p: ref.mb_energy_ref(p, jnp.zeros(()))))(pts)
    assert float(jnp.max(jnp.abs(g))) < 1.0  # MB units are O(100)


# ---------------------------------------------------------------------- mlp
@given(st.sampled_from([1, 16, 96]), st.sampled_from([None, 1, 16, 64]),
       st.sampled_from([2, 3, 10]), st.integers(0, 2**31 - 1))
def test_mlp_matches_ref(n, block, n_act, seed):
    ks = jax.random.split(_key(seed), 10)
    obs_dim, h = 6, 32
    x = jax.random.normal(ks[0], (n, obs_dim))
    w1 = jax.random.normal(ks[1], (obs_dim, h)) * 0.3
    b1 = jax.random.normal(ks[2], (h,)) * 0.1
    w2 = jax.random.normal(ks[3], (h, h)) * 0.3
    b2 = jax.random.normal(ks[4], (h,)) * 0.1
    wp = jax.random.normal(ks[5], (h, n_act)) * 0.3
    bp = jax.random.normal(ks[6], (n_act,)) * 0.1
    wv = jax.random.normal(ks[7], (h, 1)) * 0.3
    bv = jax.random.normal(ks[8], (1,)) * 0.1
    lo, v = kernels.mlp_forward(x, w1, b1, w2, b2, wp, bp, wv, bv,
                                block=block)
    lo2, v2 = ref.mlp_forward_ref(x, w1, b1, w2, b2, wp, bp, wv, bv)
    np.testing.assert_allclose(np.asarray(lo), np.asarray(lo2),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(v), np.asarray(v2),
                               rtol=1e-5, atol=1e-6)
