"""Environment dynamics invariants (beyond kernel-vs-oracle equality)."""

import jax
import jax.numpy as jnp
import numpy as np

from compile.envs import (CovidSpec, covid_init, covid_obs, covid_reset_where,
                          covid_step, make_calibration, make_env)
from compile.kernels import ref


def _run_env(name, steps=50, n=16, seed=0, policy=None):
    env = make_env(name)
    key = jax.random.PRNGKey(seed)
    f = env.init(key, n)
    rows = []
    for t in range(steps):
        key, k1, k2 = jax.random.split(key, 3)
        if env.act_type == "discrete":
            a = jax.random.randint(k1, (n,), 0, env.n_actions).astype(jnp.int32)
        else:
            a = jax.random.normal(k1, (n,))
        f, r, d = env.step(f, a, False)
        rows.append((f, r, d))
        f = env.reset_where(f, k2, d)
    return env, rows


def test_cartpole_terminates_out_of_bounds():
    s = jnp.asarray([[2.5, 0, 0, 0], [0, 0, 0.25, 0], [0, 0, 0, 0]],
                    jnp.float32)
    _, _, d = ref.cartpole_step_ref(s, jnp.zeros(3, jnp.int32))
    assert bool(d[0]) and bool(d[1]) and not bool(d[2])


def test_cartpole_force_direction():
    s = jnp.zeros((2, 4), jnp.float32)
    ns, _, _ = ref.cartpole_step_ref(s, jnp.asarray([1, 0], jnp.int32))
    # push right accelerates the cart right (velocity after one step)
    assert float(ns[0, 1]) > 0 > float(ns[1, 1])


def test_acrobot_obs_ranges():
    env, rows = _run_env("acrobot", steps=30)
    for f, r, d in rows:
        obs = np.asarray(env.obs(f))
        assert np.all(np.abs(obs[:, :4]) <= 1.0 + 1e-6)  # cos/sin
        assert np.all(np.abs(obs[:, 4]) <= ref.ACROBOT["max_vel1"] + 1e-4)
        assert np.all(np.abs(obs[:, 5]) <= ref.ACROBOT["max_vel2"] + 1e-4)


def test_acrobot_energy_injection():
    """Constant torque from rest must move the system (sanity of dynamics)."""
    s = jnp.zeros((1, 4), jnp.float32)
    for _ in range(10):
        s, _, _ = ref.acrobot_step_ref(s, jnp.asarray([2], jnp.int32))
    assert abs(float(s[0, 0])) + abs(float(s[0, 2])) > 1e-3


def test_pendulum_reward_nonpositive_and_velocity_capped():
    env, rows = _run_env("pendulum", steps=40)
    for f, r, d in rows:
        assert np.all(np.asarray(r) <= 1e-6)
        assert np.all(np.abs(np.asarray(f["phys"])[:, 1])
                      <= ref.PENDULUM["max_speed"] + 1e-5)


def test_reset_where_only_touches_masked():
    env = make_env("cartpole")
    key = jax.random.PRNGKey(1)
    f = env.init(key, 8)
    mask = jnp.asarray([1, 0, 1, 0, 0, 0, 0, 1], jnp.float32)
    f2 = env.reset_where(f, jax.random.PRNGKey(2), mask)
    old = np.asarray(f["phys"])
    new = np.asarray(f2["phys"])
    np.testing.assert_array_equal(new[mask == 0], old[np.asarray(mask) == 0])
    assert not np.allclose(new[np.asarray(mask) == 1],
                           old[np.asarray(mask) == 1])
    assert np.all(np.abs(new) <= 0.05 + 1e-6)  # fresh cartpole init range


def test_catalysis_positions_stay_in_box():
    env, rows = _run_env("catalysis_lh", steps=60)
    c = ref.CATALYSIS
    for f, r, d in rows:
        pos = np.asarray(f["pos"])
        assert np.all(pos[:, 0] >= c["x_lo"] - 1e-6)
        assert np.all(pos[:, 0] <= c["x_hi"] + 1e-6)
        assert np.all(pos[:, 1] >= c["y_lo"] - 1e-6)
        assert np.all(pos[:, 1] <= c["y_hi"] + 1e-6)


def test_catalysis_product_basin_terminates_with_bonus():
    pos = jnp.asarray([ref.MB_MIN_PRODUCT], jnp.float32) - 0.01
    pert = jnp.zeros((1,))
    ns, r, d = ref.catalysis_step_ref(pos, pert, jnp.asarray([0],
                                                             jnp.int32), 0.0)
    assert bool(d[0])
    assert float(r[0]) > ref.CATALYSIS["product_bonus"] * 0.5


def test_catalysis_er_vs_lh_start_distributions():
    lh = make_env("catalysis_lh").init(jax.random.PRNGKey(0), 512)
    er = make_env("catalysis_er").init(jax.random.PRNGKey(0), 512)
    lh_spread = float(jnp.std(lh["pos"][:, 0]))
    er_spread = float(jnp.std(er["pos"][:, 0]))
    assert er_spread > 2.0 * lh_spread  # gas-phase approach is broader
    # LH starts near the reactant minimum
    d = np.asarray(lh["pos"]) - np.asarray(ref.MB_MIN_REACTANT)
    assert np.percentile(np.hypot(d[:, 0], d[:, 1]), 90) < 0.2


def test_covid_sir_invariants():
    spec = CovidSpec()
    calib = make_calibration()
    key = jax.random.PRNGKey(0)
    f = covid_init(key, 8)
    prev_dead = np.zeros((8, spec.n_states), np.float32)
    for t in range(spec.max_steps):
        key, kg, kf = jax.random.split(key, 3)
        ga = jax.random.randint(kg, (8, spec.n_states), 0, 10).astype(jnp.int32)
        fa = jax.random.randint(kf, (8,), 0, 10).astype(jnp.int32)
        f, gr, fr = covid_step(f, calib, ga, fa, use_pallas=False)
        sir = np.asarray(f["sir"])
        assert np.all(sir >= -1e-6), f"negative compartment at t={t}"
        assert np.all(sir[..., 0] <= 1.0 + 1e-5)
        assert np.all(sir[..., 2] + 1e-7 >= prev_dead), "deaths must be monotone"
        prev_dead = sir[..., 2]


def test_covid_stringency_suppresses_infection():
    calib = make_calibration()
    f0 = covid_init(jax.random.PRNGKey(1), 4)
    fa = jnp.zeros((4,), jnp.int32)
    lock = jnp.full((4, 51), 9, jnp.int32)
    open_ = jnp.zeros((4, 51), jnp.int32)
    f_lock, f_open = f0, f0
    for _ in range(8):
        f_lock, _, _ = covid_step(f_lock, calib, lock, fa, use_pallas=False)
        f_open, _, _ = covid_step(f_open, calib, open_, fa, use_pallas=False)
    assert (float(jnp.mean(f_lock["sir"][..., 1]))
            < float(jnp.mean(f_open["sir"][..., 1])))
    # ...but lockdown damps the economy
    assert (float(jnp.mean(f_lock["econ"]))
            < float(jnp.mean(f_open["econ"])))


def test_covid_subsidy_boosts_economy_at_federal_cost():
    calib = make_calibration()
    f0 = covid_init(jax.random.PRNGKey(2), 4)
    ga = jnp.full((4, 51), 5, jnp.int32)
    f_sub, gr_s, fr_s = covid_step(f0, calib, ga,
                                   jnp.full((4,), 9, jnp.int32), False)
    f_no, gr_n, fr_n = covid_step(f0, calib, ga,
                                  jnp.zeros((4,), jnp.int32), False)
    assert float(jnp.mean(f_sub["econ"])) > float(jnp.mean(f_no["econ"]))
    assert float(jnp.mean(gr_s)) > float(jnp.mean(gr_n))


def test_covid_obs_shapes():
    spec = CovidSpec()
    f = covid_init(jax.random.PRNGKey(0), 6)
    gov_obs, fed_obs = covid_obs(f, jnp.zeros((6,)))
    assert gov_obs.shape == (6, spec.n_states, spec.gov_obs_dim)
    assert fed_obs.shape == (6, spec.fed_obs_dim)


def test_covid_reset_where():
    f = covid_init(jax.random.PRNGKey(0), 4)
    f2 = {k: v + 0.1 for k, v in f.items()}
    mask = jnp.asarray([1, 0, 0, 1], jnp.float32)
    f3 = covid_reset_where(f2, jax.random.PRNGKey(5), mask)
    # untouched rows keep the +0.1 shift
    np.testing.assert_allclose(np.asarray(f3["econ"])[1],
                               np.asarray(f2["econ"])[1], rtol=1e-6)
    # reset rows are re-initialized (deaths back to zero)
    assert float(jnp.max(jnp.abs(f3["sir"][0, :, 2]))) < 1e-6


def test_ecosystem_sustains_harvests_and_collapses():
    S = ref.ECOSYSTEM["n_species"]
    # symmetric pair community: prey grow, predators starve without prey
    r = jnp.asarray([[0.85 if i % 2 == 0 else -0.27 for i in range(S)]],
                    jnp.float32)
    a = np.full((S, S), -0.01, np.float32)
    np.fill_diagonal(a, -1.0)
    for k in range(S // 2):
        a[2 * k, 2 * k + 1] = -0.7
        a[2 * k + 1, 2 * k] = 1.1
    a = jnp.asarray(a)
    price = jnp.ones(S, jnp.float32)
    x = jnp.full((1, S), 0.8, jnp.float32)
    # unmanaged community persists
    for _ in range(200):
        x, rew, col = ref.ecosystem_step_ref(x, r, a, price,
                                             jnp.zeros(1, jnp.int32))
        assert not bool(col[0])
        assert float(rew[0]) > 0.0
    # harvesting pays the harvested amount times the price
    x0 = jnp.full((1, S), 1.0, jnp.float32)
    _, rew_h, _ = ref.ecosystem_step_ref(x0, r, a, price,
                                         jnp.asarray([1], jnp.int32))
    _, rew_w, _ = ref.ecosystem_step_ref(x0, r, a, price,
                                         jnp.zeros(1, jnp.int32))
    gain = float(rew_h[0] - rew_w[0])
    assert abs(gain - ref.ECOSYSTEM["harvest_frac"]) < 0.05
    # hammering one predator collapses the episode eventually
    x = jnp.full((1, S), 0.8, jnp.float32)
    collapsed = False
    for _ in range(200):
        x, rew, col = ref.ecosystem_step_ref(x, r, a, price,
                                             jnp.asarray([2], jnp.int32))
        if bool(col[0]):
            collapsed = True
            assert float(rew[0]) < -1.0
            break
    assert collapsed


def test_bioreactor_feed_sustains_and_stays_bounded():
    c = ref.BIOREACTOR
    nx = c["nx"]
    nu = jnp.full((2, nx), 1.0, jnp.float32)
    b = jnp.full((2, nx), 0.1, jnp.float32)
    for t in range(200):
        a = jnp.asarray([(t % 4) * 2 + 1, 0], jnp.int32)
        nu, b, rew, wash = ref.bioreactor_step_ref(nu, b, a)
        assert not bool(wash[0])
        assert float(nu.max()) <= c["n_max"] + 1e-6
        assert float(b.max()) <= c["b_max"] + 1e-6
        assert float(nu.min()) >= 0.0 and float(b.min()) >= 0.0
    # the fed reactor accumulates more biomass than the unfed one
    assert float(b[0].mean()) > float(b[1].mean())
    # feeding raises the fed port cell above a far cell
    nu0 = jnp.full((1, nx), 0.5, jnp.float32)
    b0 = jnp.full((1, nx), 0.1, jnp.float32)
    nu1, _, _, _ = ref.bioreactor_step_ref(nu0, b0,
                                           jnp.asarray([1], jnp.int32))
    fed, far = c["feed_cells"][0], c["feed_cells"][2]
    assert float(nu1[0, fed]) > float(nu1[0, far]) + 0.3
