"""L2 graph-builder tests: the fused RL iteration over the flat store."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.envs import CovidSpec, make_env
from compile.graphs import METRIC_NAMES, TrainConfig, build_graphs
from compile.graphs_covid import build_covid_graphs

CFG = TrainConfig(n_envs=16, t=8, hidden=32, use_pallas=False)


@pytest.fixture(scope="module", params=["cartpole", "pendulum",
                                        "catalysis_lh"])
def built(request):
    env = make_env(request.param)
    lo, graphs = build_graphs(env, CFG)
    jitted = {k: jax.jit(fn) for k, (fn, _) in graphs.items()}
    return env, lo, jitted


def test_init_is_seed_deterministic(built):
    env, lo, g = built
    s1 = g["init"](jnp.asarray([7.0]))
    s2 = g["init"](jnp.asarray([7.0]))
    s3 = g["init"](jnp.asarray([8.0]))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    assert not np.array_equal(np.asarray(s1), np.asarray(s3))
    assert s1.shape == (lo.total,)


def test_train_iter_preserves_shape_and_advances_stats(built):
    env, lo, g = built
    s = g["init"](jnp.asarray([1.0]))
    s2 = g["train_iter"](s)
    assert s2.shape == s.shape
    m = np.asarray(g["metrics"](s2))
    names = dict(zip(METRIC_NAMES, m))
    assert names["iter"] == 1.0
    assert names["env_steps"] == CFG.t * CFG.n_envs
    assert names["adam_t"] == 1.0
    assert np.all(np.isfinite(m))


def test_metrics_vector_matches_names(built):
    env, lo, g = built
    s = g["init"](jnp.asarray([1.0]))
    m = g["metrics"](s)
    assert m.shape == (len(METRIC_NAMES),)


def test_rollout_does_not_touch_params(built):
    env, lo, g = built
    s = g["init"](jnp.asarray([2.0]))
    p_before = np.asarray(g["get_params"](s))
    s2 = g["rollout"](s)
    p_after = np.asarray(g["get_params"](s2))
    np.testing.assert_array_equal(p_before, p_after)
    # but env state advanced
    assert not np.array_equal(np.asarray(s), np.asarray(s2))


def test_train_iter_changes_params(built):
    env, lo, g = built
    s = g["init"](jnp.asarray([2.0]))
    p0 = np.asarray(g["get_params"](s))
    p1 = np.asarray(g["get_params"](g["train_iter"](s)))
    assert not np.array_equal(p0, p1)


def test_get_set_params_roundtrip(built):
    env, lo, g = built
    s = g["init"](jnp.asarray([3.0]))
    p = g["get_params"](s)
    pz = jnp.zeros_like(p)
    s2 = g["set_params"](s, pz)
    np.testing.assert_array_equal(np.asarray(g["get_params"](s2)),
                                  np.asarray(pz))
    s3 = g["set_params"](s2, p)
    np.testing.assert_array_equal(np.asarray(s3), np.asarray(s))


def test_avg2_is_midpoint(built):
    env, lo, g = built
    s = g["init"](jnp.asarray([4.0]))
    p = g["get_params"](s)
    avg = g["avg2"](p, jnp.zeros_like(p))
    np.testing.assert_allclose(np.asarray(avg), 0.5 * np.asarray(p),
                               rtol=1e-6)


def test_determinism_of_train_iter(built):
    env, lo, g = built
    s = g["init"](jnp.asarray([5.0]))
    a = np.asarray(g["train_iter"](s))
    b = np.asarray(g["train_iter"](s))
    np.testing.assert_array_equal(a, b)


def test_cartpole_learns_under_training():
    """End-to-end learning signal through the packed graphs (small budget)."""
    env = make_env("cartpole")
    cfg = TrainConfig(n_envs=64, t=16, hidden=32, use_pallas=False)
    lo, graphs = build_graphs(env, cfg)
    ti = jax.jit(graphs["train_iter"][0])
    me = jax.jit(graphs["metrics"][0])
    s = jax.jit(graphs["init"][0])(jnp.asarray([0.0]))
    first = None
    for i in range(110):
        s = ti(s)
        if i == 9:
            first = float(np.asarray(me(s))[2])
    last = float(np.asarray(me(s))[2])
    # random policy hovers near ~22; trained must clearly exceed it
    assert last > max(first + 15.0, 50.0), f"no learning: {first} -> {last}"


def test_pallas_and_jnp_paths_agree():
    """The full fused iteration must agree between kernel paths."""
    env = make_env("cartpole")
    cfg_a = TrainConfig(n_envs=8, t=4, hidden=16, use_pallas=True)
    cfg_b = TrainConfig(n_envs=8, t=4, hidden=16, use_pallas=False)
    lo_a, ga = build_graphs(env, cfg_a)
    lo_b, gb = build_graphs(env, cfg_b)
    sa = jax.jit(ga["init"][0])(jnp.asarray([11.0]))
    sb = jax.jit(gb["init"][0])(jnp.asarray([11.0]))
    np.testing.assert_array_equal(np.asarray(sa), np.asarray(sb))
    for _ in range(2):
        sa = jax.jit(ga["train_iter"][0])(sa)
        sb = jax.jit(gb["train_iter"][0])(sb)
    np.testing.assert_allclose(np.asarray(sa), np.asarray(sb),
                               rtol=2e-3, atol=2e-4)


# ------------------------------------------------------------------- covid
@pytest.fixture(scope="module")
def covid_built():
    spec = CovidSpec()
    cfg = TrainConfig(n_envs=8, t=6, hidden=32, use_pallas=False)
    lo, graphs = build_covid_graphs(spec, cfg)
    return spec, lo, {k: jax.jit(fn) for k, (fn, _) in graphs.items()}


def test_covid_train_iter_runs_and_is_finite(covid_built):
    spec, lo, g = covid_built
    s = g["init"](jnp.asarray([1.0]))
    s2 = g["train_iter"](s)
    assert s2.shape == (lo.total,)
    m = np.asarray(g["metrics"](s2))
    assert np.all(np.isfinite(m))
    assert m[0] == 1.0


def test_covid_episode_completes_at_horizon(covid_built):
    spec, lo, g = covid_built
    s = g["init"](jnp.asarray([2.0]))
    # 6 steps/iter, horizon 52 -> after 9 iters (54 steps) every env reset once
    for _ in range(9):
        s = g["rollout"](s)
    m = np.asarray(g["metrics"](s))
    names = dict(zip(METRIC_NAMES, m))
    assert names["episodes_done"] >= 8  # all envs completed one episode
    assert abs(names["ep_len_ema"] - spec.max_steps) < 1e-3


def test_covid_two_policy_params_update(covid_built):
    spec, lo, g = covid_built
    s = g["init"](jnp.asarray([3.0]))
    p0 = np.asarray(g["get_params"](s))
    p1 = np.asarray(g["get_params"](g["train_iter"](s)))
    # both the governor block and the federal block must move
    gov_span = lo.group_span("params")[1] // 2
    assert not np.array_equal(p0[:gov_span], p1[:gov_span])
    assert not np.array_equal(p0[gov_span:], p1[gov_span:])
