"""AOT pipeline tests: HLO emission + manifest consistency."""

import json
import os

import pytest

from compile.aot import build_for, emit, tag_for, to_hlo_text
from compile.graphs import METRIC_NAMES, TrainConfig

CFG = TrainConfig(n_envs=8, t=4, hidden=16, use_pallas=False)


@pytest.fixture(scope="module")
def artifact_dir(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    emit("cartpole", CFG, str(out))
    return os.path.join(str(out), tag_for("cartpole", CFG))


def test_tag_encoding():
    assert tag_for("cartpole", CFG) == "cartpole_n8_t4_jnp"
    assert tag_for("acrobot", TrainConfig(n_envs=64, t=32)) \
        == "acrobot_n64_t32"


def test_all_graphs_emitted(artifact_dir):
    for g in ("init", "train_iter", "rollout", "metrics", "get_params",
              "set_params", "avg2"):
        path = os.path.join(artifact_dir, f"{g}.hlo.txt")
        assert os.path.exists(path), g
        text = open(path).read()
        assert text.startswith("HloModule"), g
        assert "ENTRY" in text, g


def test_manifest_consistency(artifact_dir):
    man = json.load(open(os.path.join(artifact_dir, "manifest.json")))
    assert man["env"] == "cartpole"
    assert man["state_size"] == man["layout"]["total"]
    assert man["metrics"] == list(METRIC_NAMES)
    assert man["steps_per_iter"] == CFG.n_envs * CFG.t
    # layout fields are contiguous and cover the state exactly
    offset = 0
    for f in man["layout"]["fields"]:
        assert f["offset"] == offset
        offset += f["size"]
    assert offset == man["state_size"]
    # params group span matches params_offset/params_size
    pfields = [f for f in man["layout"]["fields"]
               if f["name"] in man["layout"]["groups"]["params"]]
    assert pfields[0]["offset"] == man["params_offset"]
    assert sum(f["size"] for f in pfields) == man["params_size"]
    # graph input shapes: init takes the seed, iter graphs take the state
    assert man["graphs"]["init"]["inputs"] == [
        {"shape": [1], "dtype": "f32"}]
    assert man["graphs"]["train_iter"]["inputs"][0]["shape"] \
        == [man["state_size"]]
    assert man["graphs"]["set_params"]["inputs"][1]["shape"] \
        == [man["params_size"]]


def test_emit_is_idempotent(artifact_dir, capsys):
    mtime = os.path.getmtime(os.path.join(artifact_dir, "train_iter.hlo.txt"))
    emit("cartpole", CFG, os.path.dirname(artifact_dir))
    assert os.path.getmtime(
        os.path.join(artifact_dir, "train_iter.hlo.txt")) == mtime


def test_hlo_text_is_single_output():
    """Graphs must lower to a single non-tuple root (chainability)."""
    env_lo, graphs, _ = build_for("cartpole", CFG)
    text = to_hlo_text(*graphs["train_iter"])
    header = text.splitlines()[0]
    # entry layout result type is an array, not a tuple: ->f32[NNN]{0}}
    assert "->f32[" in header.replace(" ", ""), header
    assert "->(" not in header.replace(" ", ""), header


def test_covid_build_for_meta():
    lo, graphs, meta = build_for("covid_econ",
                                 TrainConfig(n_envs=4, t=4, hidden=16,
                                             use_pallas=False))
    assert meta["agents_per_env"] == 52
    assert set(graphs) == {"init", "train_iter", "rollout", "metrics",
                           "get_params", "set_params", "avg2"}
