#!/usr/bin/env python3
"""Bench sanity + regression gate for BENCH_engine.json.

Usage:
  bench_gate.py <fresh BENCH_engine.json> <committed BENCH_baseline.json>
  bench_gate.py <fresh BENCH_tune.json> <baseline> --only-prefix tune/

Two checks:

1. Sanity — the fresh run produced well-formed records covering the
   fused and unfused roll-out sweeps, the nn-kernel microbenches
   (tiled GEMM and the policy-forward kernel on/off pair) and the
   per-env step-kernel microbenches (one tiled/scalar pair for every
   environment in the registry), with positive throughput.
2. Regression gate — every record named in the committed baseline must
   reach at least `items_per_sec / tolerance` of its baseline value.
   The default TOLERANCE is 1.15 (tightened 2x -> 1.5 -> 1.3 -> 1.15
   as the record set and floors matured); a baseline record may carry
   its own `"tolerance"` field to gate looser where the measurement is
   inherently noisier (thread-pool spawn, queue latency, shared CI
   runners).  The committed floors are conservative sandbox estimates
   that sit well below real throughput, so the gate trips on real
   regressions (accidental debug-mode, O(n^2) paths, lost parallelism,
   a de-vectorized kernel) — not on runner noise.  Raise the floors
   (keeping tolerances) once a real CI run has measured the fleet.

Scoping rules:

* `--only-prefix P` gates only baseline records whose name starts with
  `P` and skips the full-run sanity check — the mode the `warpsci tune
  --gate-json` smoke uses (its file holds just `tune/<env>` records).
* Without `--only-prefix`, baseline records under `tune/` are skipped
  unless the fresh run actually produced them: the engine bench does
  not run the tuner.
* Baseline records ending in `/threadsN` are skipped when the fresh
  run's `sweep/threads` manifest (emitted by the bench) shows the
  machine never swept N threads — a 2-core runner legitimately has no
  `threads4` records.

A missing baseline file is a hard error (it is committed at the repo
root); any other baseline record whose name has no fresh counterpart is
also an error, so renames must update the baseline.
"""

import json
import re
import sys

TOLERANCE = 1.15

REQUIRED_PREFIXES = [
    "fused_rollout/",
    "unfused_rollout/",
    "gemm_tile/",
    "policy_forward/tiled/",
    "policy_forward/scalar/",
    "shard_scaling/sync/",
    "shard_scaling/async/",
    "serve/",
    "train_phase/",
]

# The per-env required records are derived from the "registry/envs"
# manifest record the bench emits straight out of rust envs::registry —
# registering a new environment automatically extends the gate.
REGISTRY_MANIFEST = "registry/envs"

# Which thread counts the fresh run's sweep covered (machine-derived).
SWEEP_MANIFEST = "sweep/threads"


def per_env_prefixes(envs):
    return ([f"env_step/{env}/{arm}/" for env in envs
             for arm in ("tiled", "scalar")]
            + [f"fused_rollout/{env}/" for env in envs])


def threads_of(name):
    m = re.search(r"/threads(\d+)$", name)
    return int(m.group(1)) if m else None


def main() -> int:
    args = []
    only_prefix = None
    it = iter(sys.argv[1:])
    for a in it:
        if a == "--only-prefix":
            only_prefix = next(it, None)
            if not only_prefix:
                print(__doc__)
                return 2
        elif a.startswith("--"):
            print(__doc__)
            return 2
        else:
            args.append(a)
    if len(args) != 2:
        print(__doc__)
        return 2
    fresh_path, baseline_path = args
    with open(fresh_path) as f:
        records = json.load(f)
    assert records, f"{fresh_path} is empty"
    by_name = {}
    registry_envs = None
    swept_threads = None
    for r in records:
        if r["name"] == REGISTRY_MANIFEST:
            registry_envs = r["envs"]
            continue
        if r["name"] == SWEEP_MANIFEST:
            swept_threads = {int(x) for x in r["levels"]}
            swept_threads.add(int(r["per_env_threads"]))
            continue
        assert r["items_per_sec"] > 0, r
        assert r["mean_secs"] > 0, r
        by_name[r["name"]] = r
    if only_prefix is None:
        assert registry_envs, \
            f"no {REGISTRY_MANIFEST} manifest record in {fresh_path}"
        names = set(by_name)
        for prefix in REQUIRED_PREFIXES + per_env_prefixes(registry_envs):
            assert any(n.startswith(prefix) for n in names), \
                f"no {prefix}* record in {fresh_path}: {sorted(names)}"
        print(f"{len(by_name)} bench records OK "
              f"({len(registry_envs)} registered envs)")
    else:
        print(f"{len(by_name)} bench records "
              f"(gating {only_prefix}* only)")

    with open(baseline_path) as f:
        baseline = json.load(f)
    failures = []
    gated = 0
    for b in baseline:
        name = b["name"]
        if only_prefix is not None:
            if not name.startswith(only_prefix):
                continue
        elif name.startswith("tune/") and name not in by_name:
            # the engine bench does not run the tuner; `tune/` floors
            # gate only the tune smoke (or a run that emitted them)
            continue
        n_threads = threads_of(name)
        if (swept_threads is not None and n_threads is not None
                and n_threads not in swept_threads):
            print(f"  SKIP {name}: this machine swept threads "
                  f"{sorted(swept_threads)}, not {n_threads}")
            continue
        gated += 1
        tolerance = b.get("tolerance", TOLERANCE)
        floor = b["items_per_sec"] / tolerance
        fresh = by_name.get(name)
        if fresh is None:
            failures.append(f"{name}: in baseline but missing from fresh "
                            f"run — update {baseline_path}?")
            continue
        got = fresh["items_per_sec"]
        status = "OK " if got >= floor else "FAIL"
        print(f"  {status} {name}: {got:,.0f} items/s "
              f"(gate: >= {floor:,.0f})")
        if got < floor:
            failures.append(f"{name}: {got:,.0f} < {floor:,.0f} "
                            f"(baseline {b['items_per_sec']:,.0f} "
                            f"/ {tolerance})")
    if failures:
        print("\nbench regression gate FAILED:")
        for f in failures:
            print(f"  {f}")
        return 1
    print(f"regression gate OK ({gated} baseline records gated)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
