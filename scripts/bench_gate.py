#!/usr/bin/env python3
"""Bench sanity + regression gate for BENCH_engine.json.

Usage: bench_gate.py <fresh BENCH_engine.json> <committed BENCH_baseline.json>

Two checks:

1. Sanity — the fresh run produced well-formed records covering both the
   fused and unfused roll-out sweeps, with positive throughput.
2. Regression gate — every `fused_rollout/*` record named in the committed
   baseline must reach at least HALF of its baseline `items_per_sec`.
   The 2x tolerance is deliberate: CI runs on shared hardware, and the
   committed baseline holds conservative floor values, so only
   order-of-magnitude regressions (accidental debug-mode, O(n^2) paths,
   lost parallelism) trip the gate — not runner noise.

A missing baseline file is a hard error (it is committed at the repo
root); a baseline record whose name has no fresh counterpart is also an
error, so renames must update the baseline.
"""

import json
import sys


def main() -> int:
    if len(sys.argv) != 3:
        print(__doc__)
        return 2
    fresh_path, baseline_path = sys.argv[1], sys.argv[2]
    with open(fresh_path) as f:
        records = json.load(f)
    assert records, f"{fresh_path} is empty"
    by_name = {}
    for r in records:
        assert r["items_per_sec"] > 0, r
        assert r["mean_secs"] > 0, r
        by_name[r["name"]] = r
    names = set(by_name)
    assert any(n.startswith("fused_rollout/") for n in names), names
    assert any(n.startswith("unfused_rollout/") for n in names), names
    print(f"{len(records)} bench records OK")

    with open(baseline_path) as f:
        baseline = json.load(f)
    failures = []
    for b in baseline:
        name = b["name"]
        floor = b["items_per_sec"] / 2.0
        fresh = by_name.get(name)
        if fresh is None:
            failures.append(f"{name}: in baseline but missing from fresh "
                            f"run — update {baseline_path}?")
            continue
        got = fresh["items_per_sec"]
        status = "OK " if got >= floor else "FAIL"
        print(f"  {status} {name}: {got:,.0f} items/s "
              f"(gate: >= {floor:,.0f})")
        if got < floor:
            failures.append(f"{name}: {got:,.0f} < {floor:,.0f} "
                            f"(baseline {b['items_per_sec']:,.0f} / 2)")
    if failures:
        print("\nbench regression gate FAILED:")
        for f in failures:
            print(f"  {f}")
        return 1
    print(f"regression gate OK ({len(baseline)} baseline records)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
