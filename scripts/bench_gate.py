#!/usr/bin/env python3
"""Bench sanity + regression gate for BENCH_engine.json.

Usage: bench_gate.py <fresh BENCH_engine.json> <committed BENCH_baseline.json>

Two checks:

1. Sanity — the fresh run produced well-formed records covering the
   fused and unfused roll-out sweeps, the nn-kernel microbenches
   (tiled GEMM and the policy-forward kernel on/off pair) and the
   per-env step-kernel microbenches (one tiled/scalar pair for every
   environment in the registry), with positive throughput.
2. Regression gate — every record named in the committed baseline must
   reach at least `items_per_sec / tolerance` of its baseline value.
   The default TOLERANCE is 1.3 (tightened 2x -> 1.5 -> 1.3 as the
   record set and floors matured); a baseline record may carry its own
   `"tolerance"` field to gate tighter where its floor is known to sit
   far below real throughput (the microbench floors are 5-10x
   conservative, so 1.15 is safe there).  CI runs on shared hardware,
   and the committed baseline holds conservative floor values, so the
   gate trips on real regressions (accidental debug-mode, O(n^2)
   paths, lost parallelism, a de-vectorized kernel) — not on runner
   noise.  The floors are still conservative authoring-sandbox values;
   raise them (keeping tolerances) once a real CI run has measured the
   fleet.

A missing baseline file is a hard error (it is committed at the repo
root); a baseline record whose name has no fresh counterpart is also an
error, so renames must update the baseline.
"""

import json
import sys

TOLERANCE = 1.3

REQUIRED_PREFIXES = [
    "fused_rollout/",
    "unfused_rollout/",
    "gemm_tile/",
    "policy_forward/tiled/",
    "policy_forward/scalar/",
    "shard_scaling/sync/",
    "shard_scaling/async/",
    "serve/",
]

# The per-env required records are derived from the "registry/envs"
# manifest record the bench emits straight out of rust envs::registry —
# registering a new environment automatically extends the gate.
REGISTRY_MANIFEST = "registry/envs"


def per_env_prefixes(envs):
    return ([f"env_step/{env}/{arm}/" for env in envs
             for arm in ("tiled", "scalar")]
            + [f"fused_rollout/{env}/" for env in envs])


def main() -> int:
    if len(sys.argv) != 3:
        print(__doc__)
        return 2
    fresh_path, baseline_path = sys.argv[1], sys.argv[2]
    with open(fresh_path) as f:
        records = json.load(f)
    assert records, f"{fresh_path} is empty"
    by_name = {}
    registry_envs = None
    for r in records:
        if r["name"] == REGISTRY_MANIFEST:
            registry_envs = r["envs"]
            continue
        assert r["items_per_sec"] > 0, r
        assert r["mean_secs"] > 0, r
        by_name[r["name"]] = r
    assert registry_envs, \
        f"no {REGISTRY_MANIFEST} manifest record in {fresh_path}"
    names = set(by_name)
    for prefix in REQUIRED_PREFIXES + per_env_prefixes(registry_envs):
        assert any(n.startswith(prefix) for n in names), \
            f"no {prefix}* record in {fresh_path}: {sorted(names)}"
    print(f"{len(by_name)} bench records OK "
          f"({len(registry_envs)} registered envs)")

    with open(baseline_path) as f:
        baseline = json.load(f)
    failures = []
    for b in baseline:
        name = b["name"]
        tolerance = b.get("tolerance", TOLERANCE)
        floor = b["items_per_sec"] / tolerance
        fresh = by_name.get(name)
        if fresh is None:
            failures.append(f"{name}: in baseline but missing from fresh "
                            f"run — update {baseline_path}?")
            continue
        got = fresh["items_per_sec"]
        status = "OK " if got >= floor else "FAIL"
        print(f"  {status} {name}: {got:,.0f} items/s "
              f"(gate: >= {floor:,.0f})")
        if got < floor:
            failures.append(f"{name}: {got:,.0f} < {floor:,.0f} "
                            f"(baseline {b['items_per_sec']:,.0f} "
                            f"/ {tolerance})")
    if failures:
        print("\nbench regression gate FAILED:")
        for f in failures:
            print(f"  {f}")
        return 1
    print(f"regression gate OK ({len(baseline)} baseline records)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
