#!/usr/bin/env python3
"""Golden-trajectory generator for the ecosystem and bioreactor envs.

Mirrors the rust implementations (rust/src/envs/{ecosystem,bioreactor}.rs)
operation-for-operation in numpy float32 — including the PCG64 generator
used for the shared calibration table — and prints rust-ready golden
arrays for the env unit tests, plus sanity sweeps that back the
behavioural tests (sustainability / collapse / feeding).

The jnp twins of these dynamics live in python/compile/kernels/ref.py
(`ecosystem_step_ref` / `bioreactor_step_ref`); this script is the
offline, dependency-free generator (numpy only).

Usage: python3 scripts/gen_env_goldens.py
"""

import numpy as np

F = np.float32
M64 = (1 << 64) - 1
M128 = (1 << 128) - 1
PCG_MULT = 0x2360ED051FC65DA44385DF649FCCF645


class Pcg64:
    """Bit-exact mirror of rust util::Pcg64 (PCG-XSL-RR 128/64)."""

    DEFAULT_STREAM = 0xDA3E39CB94B95BDB

    def __init__(self, seed, stream=DEFAULT_STREAM):
        self.inc = ((stream << 1) | 1) & M128
        self.state = 0
        self.next_u64()
        self.state = (self.state + seed) & M128
        self.next_u64()

    def next_u64(self):
        self.state = (self.state * PCG_MULT + self.inc) & M128
        rot = self.state >> 122
        xsl = ((self.state >> 64) ^ self.state) & M64
        return ((xsl >> rot) | (xsl << ((64 - rot) % 64))) & M64

    def next_f32(self):
        return F(self.next_u64() >> 40) / F(1 << 24)

    def uniform(self, lo, hi):
        return F(lo) + (F(hi) - F(lo)) * self.next_f32()


# ---------------------------------------------------------------- ecosystem
S = 16
ECO_DT = F(0.05)
X_MAX = F(6.0)
X_EXT = F(0.05)
HARVEST_FRAC = F(0.2)
ALIVE_BONUS = F(0.05)
COLLAPSE_PENALTY = F(25.0)
ECO_CALIB_SEED = 11


def eco_calibration():
    rng = Pcg64(ECO_CALIB_SEED, 88)
    r_base = [rng.uniform(0.7, 1.0) if i % 2 == 0
              else rng.uniform(-0.35, -0.2) for i in range(S)]
    price = [rng.uniform(0.5, 1.5) for _ in range(S)]
    a = [[rng.uniform(-0.04, 0.02) for _ in range(S)] for _ in range(S)]
    for i in range(S):
        a[i][i] = F(-1.0)
    for k in range(S // 2):
        prey, pred = 2 * k, 2 * k + 1
        a[prey][pred] = -rng.uniform(0.6, 0.8)
        a[pred][prey] = rng.uniform(0.9, 1.3)
    return r_base, price, a


def lv_deriv(x, r, a):
    ds = [F(0.0)] * S
    for f in range(S):
        acc = r[f]
        for j in range(S):
            acc = acc + a[f][j] * x[j]
        ds[f] = x[f] * acc
    return ds


def eco_step(x, r, calib, action):
    r_base, price, a = calib
    x = list(x)
    harvest = F(0.0)
    if action > 0:
        k = action - 1
        h = x[k] * HARVEST_FRAC
        x[k] = x[k] - h
        harvest = h * price[k]
    half = ECO_DT / F(2.0)
    k1 = lv_deriv(x, r, a)
    tmp = [x[f] + half * k1[f] for f in range(S)]
    k2 = lv_deriv(tmp, r, a)
    tmp = [x[f] + half * k2[f] for f in range(S)]
    k3 = lv_deriv(tmp, r, a)
    tmp = [x[f] + ECO_DT * k3[f] for f in range(S)]
    k4 = lv_deriv(tmp, r, a)
    sixth = ECO_DT / F(6.0)
    x = [x[f] + sixth * (k1[f] + F(2.0) * k2[f] + F(2.0) * k3[f]
                         + k4[f]) for f in range(S)]
    alive = 0
    for f in range(S):
        x[f] = min(max(x[f], F(0.0)), X_MAX)
        if x[f] >= X_EXT:
            alive += 1
    collapsed = alive < S
    reward = (harvest + ALIVE_BONUS * (F(alive) / F(S))
              - (COLLAPSE_PENALTY if collapsed else F(0.0)))
    return x, reward, collapsed


def eco_reset(rng, calib):
    r_base = calib[0]
    x = [rng.uniform(0.4, 1.2) for _ in range(S)]
    r = [r_base[f] * rng.uniform(0.9, 1.1) for f in range(S)]
    return x, r


# --------------------------------------------------------------- bioreactor
NX = 32
BIO_DT = F(0.1)
SUBSTEPS = 2
D_N = F(0.25)
D_B = F(0.05)
MU_MAX = F(1.2)
K_S = F(0.5)
YIELD_INV = F(2.0)
DECAY = F(0.08)
N_MAX = F(4.0)
B_MAX = F(5.0)
FEED_CELLS = [3, 11, 19, 27]
FEED_RATES = [F(0.25), F(0.75)]
FEED_COST = F(0.05)
PROD_W = F(4.0)
B_EXT = F(1e-3)
WASHOUT_PENALTY = F(10.0)


def bio_step(nu, b, action):
    nu, b = list(nu), list(b)
    port = FEED_CELLS[action // 2]
    rate = FEED_RATES[action % 2]
    nu[port] = min(nu[port] + rate, N_MAX)
    g = [F(0.0)] * NX
    for _ in range(SUBSTEPS):
        for f in range(NX):
            g[f] = MU_MAX * nu[f] / (K_S + nu[f]) * b[f]
        new_n, new_b = [F(0.0)] * NX, [F(0.0)] * NX
        for f in range(NX):
            lm = 0 if f == 0 else f - 1
            rp = NX - 1 if f == NX - 1 else f + 1
            lap_n = nu[lm] - F(2.0) * nu[f] + nu[rp]
            lap_b = b[lm] - F(2.0) * b[f] + b[rp]
            new_n[f] = min(max(nu[f] + BIO_DT * (D_N * lap_n
                                                 - YIELD_INV * g[f]),
                               F(0.0)), N_MAX)
            new_b[f] = min(max(b[f] + BIO_DT * (D_B * lap_b + g[f]
                                                - DECAY * b[f]),
                               F(0.0)), B_MAX)
        nu, b = new_n, new_b
    prod = F(0.0)
    b_sum = F(0.0)
    for f in range(NX):
        prod = prod + g[f]
        b_sum = b_sum + b[f]
    prod_mean = prod / F(NX)
    washout = b_sum / F(NX) < B_EXT
    reward = (PROD_W * prod_mean - FEED_COST * rate
              - (WASHOUT_PENALTY if washout else F(0.0)))
    return nu, b, reward, washout


def bio_reset(rng):
    nu = [rng.uniform(0.8, 1.2) for _ in range(NX)]
    b = [rng.uniform(0.05, 0.15) for _ in range(NX)]
    return nu, b


# ------------------------------------------------------------------- main
def main():
    calib = eco_calibration()
    r_base, price, _ = calib
    print("ecosystem r_base[0..4] =", [f"{v:.6g}" for v in r_base[:4]])
    print("ecosystem price[0..2]  =", [f"{v:.6g}" for v in price[:2]])

    # golden: all-0.8 community at baseline rates
    x = [F(0.8)] * S
    r = list(r_base)
    actions = [0, 1, 0, 4, 16]
    print("\nGOLDEN ecosystem (x[0..4], reward per step):")
    xs, rews = [], []
    for a in actions:
        x, reward, collapsed = eco_step(x, r, calib, a)
        assert not collapsed, "golden trajectory must not collapse"
        xs.append([x[f] for f in range(4)])
        rews.append(reward)
    for row in xs:
        print("    [" + ", ".join(f"{v:.9g}" for v in row) + "],")
    print("  rew: [" + ", ".join(f"{v:.9g}" for v in rews) + "]")

    # behavioural check 1: unmanaged community never collapses (many seeds)
    worst = None
    for seed in range(20):
        rng = Pcg64(seed)
        x, r = eco_reset(rng, calib)
        lo = min(x)
        for step in range(200):
            x, _, collapsed = eco_step(x, r, calib, 0)
            lo = min(lo, min(x))
            assert not collapsed, f"seed {seed} collapsed at {step}"
        worst = lo if worst is None else min(worst, lo)
    print(f"\nunmanaged community: min population over 20 seeds = "
          f"{worst:.4f} (extinction at {float(X_EXT)})")

    # behavioural check 2: hammering species 1 collapses (seed 5)
    rng = Pcg64(5)
    x, r = eco_reset(rng, calib)
    for step in range(200):
        x, reward, collapsed = eco_step(x, r, calib, 2)
        if collapsed:
            print(f"overharvest: collapsed at step {step}, "
                  f"reward {reward:.3f}")
            break
    else:
        raise AssertionError("overharvest did not collapse")

    # bioreactor golden: uniform reactor
    nu = [F(1.0)] * NX
    b = [F(0.1)] * NX
    actions = [1, 6, 0, 3, 7]
    probes = [3, 16, NX + 3, NX + 16]
    print("\nGOLDEN bioreactor ((idx, value) probes + reward per step):")
    for a in actions:
        nu, b, reward, washout = bio_step(nu, b, a)
        assert not washout
        state = nu + b
        cells = ", ".join(f"({p}, {state[p]:.9g})" for p in probes)
        print(f"    [{cells}],   // reward {reward:.9g}")

    # behavioural check 3: rotating high-rate feeds sustain the culture
    rng = Pcg64(6)
    nu, b = bio_reset(rng)
    total = 0.0
    for step in range(200):
        nu, b, reward, washout = bio_step(nu, b, (step % 4) * 2 + 1)
        assert not washout, f"washout at step {step}"
        total += float(reward)
    b_mean = sum(float(v) for v in b) / NX
    print(f"\nfed reactor: total reward {total:.2f}, final mean biomass "
          f"{b_mean:.3f}")

    # behavioural check 4: feed port raises its cell (from flat 0.5)
    nu = [F(0.5)] * NX
    b = [F(0.1)] * NX
    nu2, _, _, _ = bio_step(nu, b, 1)
    print(f"feed-port check: fed cell {float(nu2[FEED_CELLS[0]]):.3f} vs "
          f"far cell {float(nu2[FEED_CELLS[2]]):.3f}")


if __name__ == "__main__":
    main()
