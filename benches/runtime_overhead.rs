//! Bench: runtime micro-costs — per-`execute_b` dispatch overhead, the
//! metrics fetch, and the host round-trip the resident store avoids.
//!
//! These are the L3 numbers behind EXPERIMENTS.md §Perf: dispatch must be
//! microseconds (it bounds throughput at small n_envs), and the
//! round-trip cost is the Fig 3 "data transfer" bar in isolation.

use warpsci::bench::Bench;
use warpsci::harness::{trainer_for, HarnessOpts};
use warpsci::runtime::Device;

fn main() -> anyhow::Result<()> {
    let opts = HarnessOpts::default();
    let device = Device::cpu()?;
    let bench = Bench::from_env();
    let tag = "cartpole_n64_t16";

    // per-call dispatch: tiny graph (get_params) on a resident buffer
    let tr = trainer_for(&device, &opts, tag, 0, 1)?;
    let state = tr.graphs.init_state(0)?;
    let r = bench.run("dispatch/get_params (device-resident)", 1000.0,
                      || {
                          for _ in 0..1000 {
                              tr.graphs.get_params(&state).unwrap();
                          }
                      });
    println!("{}", r.report());

    // metrics fetch: the only recurring host transfer in the hot loop
    let r = bench.run("metrics fetch (12 floats to host)", 1000.0, || {
        for _ in 0..1000 {
            tr.graphs.metrics(&state).unwrap();
        }
    });
    println!("{}", r.report());

    // full store round-trip: what HostRoundTrip mode pays every iteration
    let size = tr.graphs.artifact.manifest.state_size as f64;
    let r = bench.run(
        &format!("full store round-trip ({size} f32)"), 100.0, || {
            for _ in 0..100 {
                let host = tr.graphs.download_state(&state).unwrap();
                tr.graphs.upload_state(&host).unwrap();
            }
        });
    println!("{}", r.report());

    // chained train_iter at small batch: dispatch-bound regime
    let mut tr = trainer_for(&device, &opts, tag, 0, 1)?;
    tr.init()?;
    let steps = tr.graphs.artifact.manifest.steps_per_iter as f64;
    let r = bench.run("train_iter n64 t16 (dispatch-bound)", steps, || {
        tr.step_train().unwrap();
    });
    println!("{}", r.report());
    Ok(())
}
