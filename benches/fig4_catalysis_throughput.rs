//! Bench: Fig 4 — catalysis roll-out/train throughput per concurrency
//! level and mechanism (LH vs ER must cost the same: identical encoding).

use warpsci::bench::Bench;
use warpsci::harness::{sweep_tags, trainer_for, HarnessOpts};
use warpsci::runtime::Device;

fn main() -> anyhow::Result<()> {
    let opts = HarnessOpts::default();
    let device = Device::cpu()?;
    let bench = Bench::from_env();
    for mech in ["lh", "er"] {
        let env = format!("catalysis_{mech}");
        for (n, tag) in sweep_tags(&opts, &env, 32)? {
            let mut tr = trainer_for(&device, &opts, &tag, 0, 1)?;
            tr.init()?;
            let steps = tr.graphs.artifact.manifest.steps_per_iter as f64;
            let r = bench.run(&format!("{env}/train_iter/n{n}"), steps,
                              || { tr.step_train().unwrap(); });
            println!("{}", r.report());
        }
    }
    Ok(())
}
