//! Bench: Fig 2(a) — classic-control throughput vs concurrency.
//!
//! Measures roll-out and roll-out+train steps/second for every available
//! cartpole/acrobot artifact (run `make artifacts-bench` for the full
//! sweep) and reports the scaling factor between consecutive levels —
//! the paper's claim is near-perfect linearity.

use warpsci::bench::Bench;
use warpsci::harness::{sweep_tags, trainer_for, HarnessOpts};
use warpsci::runtime::Device;

fn main() -> anyhow::Result<()> {
    let opts = HarnessOpts::default();
    let device = Device::cpu()?;
    let bench = Bench::from_env();
    for env in ["cartpole", "acrobot"] {
        let tags = sweep_tags(&opts, env, 32)?;
        if tags.is_empty() {
            eprintln!("no {env} artifacts; run `make artifacts` first");
            continue;
        }
        let mut prev: Option<(usize, f64)> = None;
        for (n, tag) in tags {
            if tag.ends_with("_jnp") || tag.ends_with("_nstep") {
                continue;
            }
            let mut tr = trainer_for(&device, &opts, &tag, 0, 1)?;
            tr.init()?;
            let steps = tr.graphs.artifact.manifest.steps_per_iter as f64;
            let roll = bench.run(&format!("{env}/rollout/n{n}"), steps,
                                 || { tr.step_rollout().unwrap(); });
            println!("{}", roll.report());
            let mut tr = trainer_for(&device, &opts, &tag, 0, 1)?;
            tr.init()?;
            let train = bench.run(&format!("{env}/train_iter/n{n}"), steps,
                                  || { tr.step_train().unwrap(); });
            println!("{}", train.report());
            if let Some((pn, psps)) = prev {
                println!("    scaling n{pn} -> n{n}: x{:.2} measured vs \
                          x{:.1} ideal",
                         roll.items_per_sec() / psps, n as f64 / pn as f64);
            }
            prev = Some((n, roll.items_per_sec()));
        }
    }
    Ok(())
}
