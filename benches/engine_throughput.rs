//! Bench: the SoA batch engine — raw vector stepping, a thread-count ×
//! environment sweep of the fused in-worker roll-out against the seed
//! architecture (serial inference + per-tick engine step), a per-env
//! fused steps/sec sweep over the whole environment registry, and two
//! kernel on/off microbench families: the `nn::kernels` compute layer
//! (tiled GEMM vs the scalar reference) and the `envs::kernels` step
//! layer (per-env lane-tiled `step_all` vs the scalar `step_all_ref`
//! oracle), i.e. the paper's "thousands of concurrent environments on
//! one device" axis realized on CPU.
//!
//! Each result is printed human-readably and as one JSON line, and the
//! whole run is written as a JSON array to `BENCH_engine.json` at the
//! repo root — the perf-trajectory baseline for future changes
//! (`scripts/bench_gate.py` gates the `fused_rollout/*`, `gemm_tile/*`,
//! `policy_forward/tiled/*`, per-env `env_step/*`, multi-shard
//! `shard_scaling/{sync,async}/*`, inference-serving `serve/*` and
//! isolated-update `train_phase/*` records against
//! `BENCH_baseline.json`).
//!
//! Thread counts for the sweep families are derived from the machine
//! (`thread_levels`: the 1..8 power-of-two ladder clipped to available
//! cores) rather than hard-coded, and the run emits a `sweep/threads`
//! manifest record naming the levels it covered.
//!
//! Env overrides: `WARPSCI_BENCH_FAST=1` for a smoke run.

use warpsci::bench::Bench;
use warpsci::coordinator::{Backend, CpuEngine, CpuEngineConfig};
use warpsci::engine::BatchEngine;
use warpsci::envs::registry;
use warpsci::nn::mlp::{Cache, RefCache};
use warpsci::nn::{kernels, Mlp, TiledPolicy};
use warpsci::util::{Json, Pcg64};

/// The roll-out structure of the seed architecture: policy forward +
/// categorical sampling run *serially* on the caller thread from one
/// shared action stream, then one engine round per tick — the
/// serial-inference / parallel-step alternation the fused roll-out
/// eliminates.  Note the per-tick rounds here already run on the
/// persistent pool and the forward already runs on the tiled kernels,
/// so this sweep isolates the *fusion* win; the kernel win itself is
/// measured by the `policy_forward/*` pair below.
struct UnfusedRollout {
    engine: BatchEngine,
    tiled: TiledPolicy,
    rng: Pcg64,
    cache: Cache,
    actions: Vec<u32>,
    row: Vec<f32>,
}

impl UnfusedRollout {
    fn new(env: &str, n_envs: usize, threads: usize) -> UnfusedRollout {
        let engine = BatchEngine::by_name(env, n_envs, threads, 0)
            .expect("engine");
        let mut init_rng = Pcg64::with_stream(0, u64::MAX - 1);
        let policy = Mlp::init(engine.obs_dim(), 64, engine.n_actions(),
                               &mut init_rng);
        let rows = n_envs * engine.n_agents();
        let n_actions = engine.n_actions();
        UnfusedRollout {
            engine,
            tiled: TiledPolicy::new(&policy),
            rng: Pcg64::with_stream(0, u64::MAX - 2),
            cache: Cache::default(),
            actions: vec![0; rows],
            row: vec![0.0; n_actions],
        }
    }

    fn rollout(&mut self, t: usize) {
        let rows = self.engine.n_envs() * self.engine.n_agents();
        let n_actions = self.engine.n_actions();
        for _ in 0..t {
            self.tiled.forward(&self.engine.obs, rows, &mut self.cache);
            for row in 0..rows {
                for j in 0..n_actions {
                    self.row[j] = self.cache.logp[j * rows + row];
                }
                self.actions[row] = self.rng.categorical(&self.row) as u32;
            }
            self.engine.step(&self.actions);
        }
    }
}

/// Thread counts for the sweep families, derived from the machine
/// instead of hard-coded: the power-of-two ladder 1..8 clipped to the
/// available cores (plus the core count itself on small non-power-of-2
/// machines), so a 2-core CI runner no longer times an oversubscribed
/// 8-thread pool and an 8+-core box reproduces the historical
/// [1, 2, 4, 8] record names exactly.
fn thread_levels(cores: usize) -> Vec<usize> {
    let mut levels: Vec<usize> =
        [1usize, 2, 4, 8].iter().copied().filter(|&x| x <= cores).collect();
    if levels.is_empty() {
        levels.push(1);
    }
    if cores < 8 && !levels.contains(&cores) {
        levels.push(cores);
        levels.sort_unstable();
    }
    levels
}

fn main() -> anyhow::Result<()> {
    let bench = Bench::from_env();
    let cores = std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(1);
    let levels = thread_levels(cores);
    // fixed-thread records (per-env fused, train) use this count so
    // their names stay `threads4` anywhere with >= 4 cores
    let per_env_threads = 4usize.min(cores.max(1));
    let mut records: Vec<Json> = Vec::new();
    let emit = |records: &mut Vec<Json>,
                r: &warpsci::bench::BenchResult| {
        println!("{}", r.report());
        let json = r.to_json();
        println!("{json}");
        records.push(json);
    };

    // nn kernel micro-benches: one dense tanh layer at the training
    // shape (4096 rows x 64 -> 64), tiled vs the scalar reference loop —
    // the isolated kernel-path on/off comparison
    {
        let (n, in_dim, out_dim) = (4096usize, 64usize, 64usize);
        let mut rng = Pcg64::new(1);
        let x_cols: Vec<f32> =
            (0..n * in_dim).map(|_| rng.normal()).collect();
        let wt: Vec<f32> =
            (0..out_dim * in_dim).map(|_| rng.normal() * 0.1).collect();
        let bias: Vec<f32> = (0..out_dim).map(|_| rng.normal()).collect();
        let mut out = vec![0f32; n * out_dim];
        let r = bench.run(
            &format!("gemm_tile/dense{in_dim}x{out_dim}/n{n}"),
            n as f64,
            || {
                kernels::dense_cols(&x_cols, n, in_dim, &wt, &bias,
                                    out_dim, true, &mut out);
            });
        emit(&mut records, &r);

        // the pre-kernel inner loop: row-major x, stride-`out_dim`
        // weight reads, one scalar accumulator per output
        let mut x_rows = vec![0f32; n * in_dim];
        kernels::transpose(&x_cols, in_dim, n, &mut x_rows);
        let mut w = vec![0f32; in_dim * out_dim];
        kernels::transpose(&wt, out_dim, in_dim, &mut w);
        let r = bench.run(
            &format!("gemm_scalar/dense{in_dim}x{out_dim}/n{n}"),
            n as f64,
            || {
                for i in 0..n {
                    let xi = &x_rows[i * in_dim..(i + 1) * in_dim];
                    for j in 0..out_dim {
                        let mut acc = bias[j];
                        for k in 0..in_dim {
                            acc += xi[k] * w[k * out_dim + j];
                        }
                        out[i * out_dim + j] = acc.tanh();
                    }
                }
            });
        emit(&mut records, &r);
    }

    // full policy forward (2x64 tanh + heads), tiled kernels vs the
    // scalar reference oracle on an identical batch
    {
        let (n, od, acts) = (4096usize, 4usize, 2usize);
        let mut rng = Pcg64::new(2);
        let policy = Mlp::init(od, 64, acts, &mut rng);
        let tiled = TiledPolicy::new(&policy);
        let x_rows: Vec<f32> = (0..n * od).map(|_| rng.normal()).collect();
        let mut x_cols = vec![0f32; n * od];
        kernels::transpose(&x_rows, n, od, &mut x_cols);
        let mut cache = Cache::default();
        let r = bench.run(&format!("policy_forward/tiled/n{n}"), n as f64,
                          || {
                              tiled.forward(&x_cols, n, &mut cache);
                          });
        emit(&mut records, &r);
        let mut ref_cache = RefCache::default();
        let r = bench.run(&format!("policy_forward/scalar/n{n}"),
                          n as f64,
                          || {
                              policy.forward_ref(&x_rows, n,
                                                 &mut ref_cache);
                          });
        emit(&mut records, &r);
    }

    // raw SoA stepping (no policy): constant action pattern per lane
    let mut step_shapes: Vec<(usize, usize)> =
        levels.iter().map(|&th| (4096usize, th)).collect();
    step_shapes.push((16384, per_env_threads));
    for (n_envs, threads) in step_shapes {
        let mut eng = BatchEngine::by_name("cartpole", n_envs, threads, 0)?;
        let actions: Vec<u32> =
            (0..n_envs).map(|i| (i % 2) as u32).collect();
        let ticks = 50usize;
        let r = bench.run(
            &format!("engine_step/cartpole/n{n_envs}/threads{threads}"),
            (ticks * n_envs) as f64,
            || {
                for _ in 0..ticks {
                    eng.step(&actions);
                }
            });
        emit(&mut records, &r);
    }

    // per-env step-kernel microbench across the whole registry: the
    // lane-tiled columnar step_all vs the scalar step_all_ref oracle
    // (the env-kernel on/off toggle), direct kernel dispatch on one
    // resident state slab — no pool round, no obs refresh
    for spec in registry::SPECS.iter() {
        let env = (spec.make_batch)();
        let n = spec.bench_n_envs;
        let rows = n * spec.n_agents;
        let mut state = vec![0f32; spec.state_dim * n];
        for i in 0..n {
            let mut rng = Pcg64::with_stream(0, i as u64);
            env.reset_lane(&mut state, n, i, &mut rng);
        }
        let mut state_ref = state.clone();
        let n_act = spec.n_actions as u32;
        let actions: Vec<u32> =
            (0..rows).map(|r| r as u32 % n_act).collect();
        let mut rewards = vec![0f32; rows];
        let mut dones = vec![0f32; n];
        let ticks = if spec.n_agents > 1 { 5 } else { 20 };
        let r = bench.run(
            &format!("env_step/{}/tiled/n{n}", spec.name),
            (ticks * n) as f64,
            || {
                for _ in 0..ticks {
                    env.step_all(&mut state, n, &actions, &mut [],
                                 &mut rewards, &mut dones);
                }
            });
        emit(&mut records, &r);
        let r = bench.run(
            &format!("env_step/{}/scalar/n{n}", spec.name),
            (ticks * n) as f64,
            || {
                for _ in 0..ticks {
                    env.step_all_ref(&mut state_ref, n, &actions,
                                     &mut [], &mut rewards, &mut dones);
                }
            });
        emit(&mut records, &r);
    }

    // the headline sweep: fused in-worker roll-out vs the seed's
    // serial-inference roll-out structure (on the same pooled engine),
    // across thread counts and envs — fused must win everywhere, most
    // at high thread counts, where the unfused path is bound by its
    // serial phase and per-tick rounds
    for (env, n_envs, t) in [("cartpole", 4096usize, 8usize),
                             ("covid_econ", 128, 4)] {
        for &threads in &levels {
            let mut eng = CpuEngine::new(CpuEngineConfig {
                threads,
                ..CpuEngineConfig::new(env, n_envs, t)
            })?;
            let r = bench.run(
                &format!("fused_rollout/{env}/n{n_envs}/t{t}/\
                          threads{threads}"),
                eng.steps_per_iter() as f64,
                || {
                    eng.rollout_iter().unwrap();
                });
            emit(&mut records, &r);

            let mut unfused = UnfusedRollout::new(env, n_envs, threads);
            let r = bench.run(
                &format!("unfused_rollout/{env}/n{n_envs}/t{t}/\
                          threads{threads}"),
                (n_envs * t) as f64,
                || {
                    unfused.rollout(t);
                });
            emit(&mut records, &r);
        }
    }

    // per-env fused steps/sec at each env's registry bench shape
    // (cartpole and covid_econ are covered by the sweep above)
    for spec in registry::SPECS
        .iter()
        .filter(|s| s.name != "cartpole" && s.name != "covid_econ")
    {
        let (n_envs, t) = (spec.bench_n_envs, spec.bench_t);
        let mut eng = CpuEngine::new(CpuEngineConfig {
            threads: per_env_threads,
            ..CpuEngineConfig::new(spec.name, n_envs, t)
        })?;
        let r = bench.run(
            &format!("fused_rollout/{}/n{n_envs}/t{t}/threads{}",
                     spec.name, per_env_threads),
            eng.steps_per_iter() as f64,
            || {
                eng.rollout_iter().unwrap();
            });
        emit(&mut records, &r);
    }

    // fused roll-out + A2C train iteration
    for (env, n_envs, t) in [("cartpole", 4096usize, 8usize),
                             ("covid_econ", 128, 4)] {
        let mut eng = CpuEngine::new(CpuEngineConfig {
            threads: per_env_threads,
            ..CpuEngineConfig::new(env, n_envs, t)
        })?;
        let r = bench.run(
            &format!("cpu_engine_train/{env}/n{n_envs}/t{t}/threads{}",
                     per_env_threads),
            eng.steps_per_iter() as f64,
            || {
                eng.train_iter().unwrap();
            });
        emit(&mut records, &r);
    }

    // the train phase in isolation: one A2C/Adam update over a captured
    // trajectory (`CpuEngine::update_only`), pool-parallel vs the
    // single-thread serial oracle.  Both arms run the identical
    // config-fixed slice partition, so the trained parameters are
    // bit-identical — only the wall clock may differ, which is exactly
    // what the `train_phase/*` gate records pin (the par floor sits
    // above the serial floor, encoding that the sharded update must
    // beat the serial oracle on a multi-core runner)
    for (env, n_envs, t) in [("cartpole", 4096usize, 8usize),
                             ("ecosystem", 1024, 8)] {
        for (arm, threads) in
            [("serial".to_string(), 1usize),
             (format!("par/threads{per_env_threads}"), per_env_threads)]
        {
            let mut eng = CpuEngine::new(CpuEngineConfig {
                threads,
                ..CpuEngineConfig::new(env, n_envs, t)
            })?;
            eng.train_iter()?; // capture one trajectory to re-update
            let r = bench.run(
                &format!("train_phase/{env}/{arm}"),
                eng.steps_per_iter() as f64,
                || {
                    eng.update_only().unwrap();
                });
            emit(&mut records, &r);
        }
    }

    // multi-shard scaling: the lockstep sync collective vs the async
    // parameter server, both over the in-process CPU graph device.
    // Sync steps its shards serially on this thread; async gives each
    // shard a worker thread, so at 4+ shards the async record must at
    // least match sync on any multi-core runner (the gate's floors
    // encode that ordering conservatively).
    {
        use warpsci::config::RunConfig;
        use warpsci::coordinator::{AsyncShardTrainer, MultiShardTrainer};
        use warpsci::runtime::CpuDevice;

        let (env, n_envs, t) = ("cartpole", 256usize, 8usize);
        let (iters, sync_every) = (8usize, 2usize);
        let device = CpuDevice::new();
        let artifact = device.artifact(env, n_envs, t)?;
        for shards in [1usize, 4] {
            let cfg = RunConfig {
                env: env.into(),
                n_envs,
                t,
                iters,
                seed: 0,
                shards,
                sync_every,
                max_staleness: 1,
                ..Default::default()
            };
            let steps = (iters * n_envs * t * shards) as f64;
            let mut ms =
                MultiShardTrainer::new(&device, &artifact, cfg.clone())?;
            let mut iter_idx = 0usize;
            let r = bench.run(
                &format!("shard_scaling/sync/{env}/shards{shards}"),
                steps,
                || {
                    for _ in 0..iters {
                        ms.step(iter_idx).unwrap();
                        iter_idx += 1;
                    }
                });
            emit(&mut records, &r);

            // each call is one whole async job (spawn, train, join) —
            // thread + in-memory compile overhead is part of the cost
            let tr = AsyncShardTrainer::new(&device, &artifact, cfg)?;
            let r = bench.run(
                &format!("shard_scaling/async/{env}/shards{shards}"),
                steps,
                || {
                    tr.run().unwrap();
                });
            emit(&mut records, &r);
        }
    }

    // micro-batched inference serving: closed-loop clients against the
    // in-process policy server (each sample = every client playing
    // cartpole end-to-end through the request queue) — the requests/s
    // records behind the `serve/*` gate prefixes
    {
        use warpsci::harness::serve::drive_clients;
        use warpsci::serve::{PolicyServer, ServeConfig};

        let per_client = 64usize;
        for clients in [1usize, 8, 64] {
            let server = PolicyServer::start(ServeConfig {
                envs: vec!["cartpole".into()],
                ..ServeConfig::default()
            })?;
            let r = bench.run(
                &format!("serve/cartpole/clients{clients}"),
                (clients * per_client) as f64,
                || {
                    drive_clients(&server, "cartpole", clients,
                                  per_client)
                        .unwrap();
                });
            emit(&mut records, &r);
            server.stop()?;
        }
    }

    // thread-sweep manifest record: which thread counts this machine's
    // sweep actually covered (derived from available_parallelism above)
    // so scripts/bench_gate.py can skip baseline `threadsN` records a
    // smaller runner legitimately never produced
    {
        let mut m = std::collections::BTreeMap::new();
        m.insert("name".to_string(),
                 Json::Str("sweep/threads".to_string()));
        m.insert("levels".to_string(),
                 Json::Arr(levels.iter()
                     .map(|&t| Json::Num(t as f64))
                     .collect()));
        m.insert("per_env_threads".to_string(),
                 Json::Num(per_env_threads as f64));
        m.insert("cores".to_string(), Json::Num(cores as f64));
        records.push(Json::Obj(m));
    }

    // registry manifest record: the env-name list this run covered,
    // emitted straight from envs::registry so scripts/bench_gate.py can
    // derive its per-env required records without a hand-kept mirror
    {
        let mut m = std::collections::BTreeMap::new();
        m.insert("name".to_string(),
                 Json::Str("registry/envs".to_string()));
        m.insert("envs".to_string(),
                 Json::Arr(registry::names()
                     .map(|n| Json::Str(n.to_string()))
                     .collect()));
        records.push(Json::Obj(m));
    }

    let out = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_engine.json");
    std::fs::write(&out, format!("{}\n", Json::Arr(records)))?;
    println!("wrote {}", out.display());
    Ok(())
}
