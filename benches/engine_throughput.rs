//! Bench: the SoA batch engine — raw vector stepping and the full
//! policy-in-the-loop roll-out, across replica counts and shard threads.
//!
//! The headline configuration steps 4096 cartpole replicas across 4 shard
//! threads, i.e. the paper's "thousands of concurrent environments on one
//! device" axis realized on CPU.  Each result is printed human-readably
//! and as one JSON line (the `bench` module's machine-readable output).
//!
//! Env overrides: `WARPSCI_BENCH_FAST=1` for a smoke run.

use warpsci::bench::Bench;
use warpsci::coordinator::{Backend, CpuEngine, CpuEngineConfig};
use warpsci::engine::BatchEngine;

fn main() -> anyhow::Result<()> {
    let bench = Bench::from_env();

    // raw SoA stepping (no policy): constant action pattern per lane
    for (n_envs, threads) in [(4096usize, 1usize), (4096, 2), (4096, 4),
                              (16384, 4)] {
        let mut eng = BatchEngine::by_name("cartpole", n_envs, threads, 0)?;
        let actions: Vec<u32> =
            (0..n_envs).map(|i| (i % 2) as u32).collect();
        let ticks = 50usize;
        let r = bench.run(
            &format!("engine_step/cartpole/n{n_envs}/threads{threads}"),
            (ticks * n_envs) as f64,
            || {
                for _ in 0..ticks {
                    eng.step(&actions);
                }
            });
        println!("{}", r.report());
        println!("{}", r.to_json());
    }

    // other envs at the headline shard count
    for env in ["acrobot", "pendulum", "catalysis_lh", "covid_econ"] {
        let n_envs = if env == "covid_econ" { 512 } else { 4096 };
        let mut eng = BatchEngine::by_name(env, n_envs, 4, 0)?;
        let rows = n_envs * eng.n_agents();
        let n_act = eng.n_actions() as u32;
        let actions: Vec<u32> =
            (0..rows).map(|i| i as u32 % n_act).collect();
        let ticks = if env == "covid_econ" { 10 } else { 50 };
        let r = bench.run(
            &format!("engine_step/{env}/n{n_envs}/threads4"),
            (ticks * n_envs) as f64,
            || {
                for _ in 0..ticks {
                    eng.step(&actions);
                }
            });
        println!("{}", r.report());
        println!("{}", r.to_json());
    }

    // full backend roll-out: policy inference + sampling + engine step
    for threads in [1usize, 4] {
        let mut eng = CpuEngine::new(CpuEngineConfig {
            threads,
            ..CpuEngineConfig::new("cartpole", 4096, 8)
        })?;
        let r = bench.run(
            &format!("cpu_engine_rollout/cartpole/n4096/threads{threads}"),
            eng.steps_per_iter() as f64,
            || {
                eng.rollout_iter().unwrap();
            });
        println!("{}", r.report());
        println!("{}", r.to_json());
    }

    // fused roll-out + A2C train iteration
    let mut eng = CpuEngine::new(CpuEngineConfig {
        threads: 4,
        ..CpuEngineConfig::new("cartpole", 4096, 8)
    })?;
    let r = bench.run("cpu_engine_train/cartpole/n4096/threads4",
                      eng.steps_per_iter() as f64,
                      || {
                          eng.train_iter().unwrap();
                      });
    println!("{}", r.report());
    println!("{}", r.to_json());
    Ok(())
}
