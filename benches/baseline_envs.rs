//! Bench: pure-rust environment step rates (the baseline's substrate),
//! the scalar-vs-SoA stepping gap, and the serialization layer's cost
//! per megabyte.

use warpsci::baseline::RolloutWorker;
use warpsci::bench::Bench;
use warpsci::engine::BatchEngine;
use warpsci::envs::make_cpu_env;
use warpsci::nn::Mlp;
use warpsci::util::Pcg64;

fn main() -> anyhow::Result<()> {
    let bench = Bench::from_env();

    // raw scalar env physics throughput (no policy): the per-instance
    // `Box<dyn CpuEnv>` path, for comparison against engine_throughput's
    // SoA numbers
    for name in ["cartpole", "acrobot", "pendulum", "covid_econ",
                 "catalysis_lh"] {
        let mut env = make_cpu_env(name)?;
        let mut rng = Pcg64::new(0);
        env.reset(&mut rng);
        let na = env.n_agents();
        let n_act = env.n_actions();
        let mut rewards = vec![0f32; na];
        let actions: Vec<usize> = (0..na).map(|i| i % n_act).collect();
        let iters = 20_000usize;
        let r = bench.run(&format!("env_step/{name}"), iters as f64, || {
            for _ in 0..iters {
                if env.step(&actions, &mut rng, &mut rewards) {
                    env.reset(&mut rng);
                }
            }
        });
        println!("{}", r.report());
    }

    // SoA engine at the same tiny batch size the worker uses, single
    // shard — isolates the dispatch win from the parallelism win
    for name in ["cartpole", "covid_econ"] {
        let n_envs = 4;
        let mut eng = BatchEngine::by_name(name, n_envs, 1, 0)?;
        let rows = n_envs * eng.n_agents();
        let n_act = eng.n_actions() as u32;
        let actions: Vec<u32> =
            (0..rows).map(|i| i as u32 % n_act).collect();
        let ticks = 5_000usize;
        let r = bench.run(&format!("engine_step/{name}/4envs"),
                          (ticks * n_envs) as f64, || {
                              for _ in 0..ticks {
                                  eng.step(&actions);
                              }
                          });
        println!("{}", r.report());
    }

    // worker roll-out incl. policy inference (the baseline hot loop)
    for name in ["cartpole", "covid_econ"] {
        let probe = make_cpu_env(name)?;
        let mut rng = Pcg64::new(1);
        let policy = Mlp::init(probe.obs_dim(), 64, probe.n_actions(),
                               &mut rng);
        let mut worker = RolloutWorker::new(name, 4, policy, 0)?;
        let t = 16usize;
        let r = bench.run(&format!("worker_rollout/{name}/4envs"),
                          (t * 4) as f64, || {
                              std::hint::black_box(worker.rollout(t));
                          });
        println!("{}", r.report());
    }

    // serialization cost
    let mut rng = Pcg64::new(2);
    let policy = Mlp::init(7, 64, 10, &mut rng);
    let mut worker = RolloutWorker::new("covid_econ", 8, policy, 0)?;
    let batch = worker.rollout(13);
    let bytes = batch.serialize();
    let mb = bytes.len() as f64 / 1e6;
    let r = bench.run(&format!("serialize+deserialize ({mb:.2} MB batch)"),
                      1.0, || {
        let b = batch.serialize();
        std::hint::black_box(
            warpsci::baseline::TrajectoryBatch::deserialize(&b).unwrap());
    });
    println!("{}", r.report());
    Ok(())
}
