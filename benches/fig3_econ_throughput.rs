//! Bench: Fig 3 — COVID economy: WarpSci vs the distributed baseline.
//!
//! End-to-end iteration benchmark of both systems at matched workloads,
//! plus the econ scaling series (right panel).

use warpsci::baseline::{DistributedConfig, DistributedSystem};
use warpsci::bench::Bench;
use warpsci::harness::{sweep_tags, trainer_for, HarnessOpts};
use warpsci::runtime::Device;

fn main() -> anyhow::Result<()> {
    let opts = HarnessOpts::default();
    let device = Device::cpu()?;
    let bench = Bench::from_env();

    // WarpSci across available econ sizes
    for (n, tag) in sweep_tags(&opts, "covid_econ", 13)? {
        let mut tr = trainer_for(&device, &opts, &tag, 0, 1)?;
        tr.init()?;
        let steps = tr.graphs.artifact.manifest.steps_per_iter as f64;
        let r = bench.run(&format!("warpsci/econ/train_iter/n{n}"), steps,
                          || { tr.step_train().unwrap(); });
        println!("{}", r.report());
    }

    // distributed baseline: one full round (rollout+transfer+train)
    for workers in [4usize, 16] {
        let cfg = DistributedConfig {
            env: "covid_econ".into(),
            n_workers: workers,
            envs_per_worker: 4,
            t: 13,
            ..Default::default()
        };
        let steps = (cfg.t * cfg.n_workers * cfg.envs_per_worker) as f64;
        let mut sys = DistributedSystem::new(cfg)?;
        let r = bench.run(&format!("distributed/econ/round/w{workers}"),
                          steps, || { sys.round().unwrap(); });
        println!("{}", r.report());
        println!("    phases so far: rollout {:.3}s transfer {:.3}s \
                  train {:.3}s", sys.timer.secs("rollout"),
                 sys.timer.secs("transfer"), sys.timer.secs("train"));
    }
    Ok(())
}
