//! Train-then-serve: the paper's "millions of users" loop in-process.
//!
//! Trains cartpole on the SoA cpu-engine backend, publishes the policy
//! as an atomic checkpoint, and serves it through the micro-batching
//! [`PolicyServer`] to a pool of closed-loop clients.  A second, longer
//! training run then publishes a new checkpoint *while the server is
//! up* — the server hot-reloads it between batches, and the report
//! shows the swap (reloads >= 2, no request dropped).
//!
//! Run:  cargo run --release --example serving
//! Env:  WARPSCI_EXAMPLE_ITERS=N   shorten the training runs
//!
//! [`PolicyServer`]: warpsci::serve::PolicyServer

use anyhow::Result;

use warpsci::coordinator::{Backend, CpuEngine, CpuEngineConfig};
use warpsci::harness::serve::drive_clients;
use warpsci::serve::{PolicyServer, ServeConfig};
use warpsci::store::Checkpoint;

/// Train `iters` more iterations on `eng` and publish the result.
fn train_and_publish(eng: &mut CpuEngine, iters: usize,
                     dir: &std::path::Path) -> Result<()> {
    for _ in 0..iters {
        eng.train_iter()?;
    }
    let row = eng.metrics_row(1.0)?;
    let ck = Checkpoint {
        tag: "serving-example".into(),
        iter: row.iter as u64,
        version: row.iter as u64,
        rng: None,
        params: eng.policy_facade().flat_params(),
    };
    ck.save(dir, "latest")?;
    println!("published checkpoint at iter {} (return EMA {:.1})",
             row.iter as u64, row.ep_return_ema);
    Ok(())
}

fn main() -> Result<()> {
    let iters = warpsci::util::env_usize("WARPSCI_EXAMPLE_ITERS", 40);
    let dir = std::env::temp_dir().join(format!(
        "warpsci_serving_example_{}", std::process::id()));
    std::fs::create_dir_all(&dir)?;

    println!("training cartpole ({iters} iters) ...");
    let mut eng = CpuEngine::new(CpuEngineConfig {
        seed: 3,
        ..CpuEngineConfig::new("cartpole", 512, 16)
    })?;
    train_and_publish(&mut eng, iters, &dir)?;

    let server = PolicyServer::start(ServeConfig {
        envs: vec!["cartpole".into()],
        checkpoint_dir: Some(dir.clone()),
        reload_poll_ms: 5,
        ..ServeConfig::default()
    })?;
    println!("serving the published policy to 4 closed-loop clients ...");
    drive_clients(&server, "cartpole", 4, 64)?;

    println!("training {iters} more iters while the server is up ...");
    train_and_publish(&mut eng, iters, &dir)?;
    std::thread::sleep(std::time::Duration::from_millis(50));
    drive_clients(&server, "cartpole", 4, 64)?;

    let report = server.stop()?;
    println!("{}", report.summary());
    anyhow::ensure!(report.requests == 2 * 4 * 64,
                    "dropped requests: answered {}", report.requests);
    anyhow::ensure!(report.reloads >= 2,
                    "hot reload did not trigger (reloads {})",
                    report.reloads);
    std::fs::remove_dir_all(&dir).ok();
    println!("ok: served both checkpoint versions without dropping a \
              request");
    Ok(())
}
