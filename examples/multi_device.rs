//! Multi-shard data-parallel training (the paper's multi-GPU axis).
//!
//! Runs 4 independent device-resident stores with distinct seeds and
//! periodically tree-averages their policy parameters via the on-device
//! `avg2` graph — the orchestration path a multi-GPU WarpSci deployment
//! runs, demonstrated on the in-process CPU device (a `pjrt` build runs
//! the identical code over PJRT executables).
//!
//! Run:  cargo run --release --example multi_device
//! Env:  WARPSCI_EXAMPLE_ITERS=N   shorten the run (CI smoke uses 8)

use anyhow::Result;

use warpsci::config::RunConfig;
use warpsci::coordinator::MultiShardTrainer;
use warpsci::runtime::CpuDevice;
use warpsci::util::env_usize;

fn main() -> Result<()> {
    let iters = env_usize("WARPSCI_EXAMPLE_ITERS", 120);
    let device = CpuDevice::new();
    let artifact = device.artifact("cartpole", 64, 16)?;
    let cfg = RunConfig {
        env: "cartpole".into(),
        n_envs: 64,
        t: 16,
        iters,
        seed: 0,
        shards: 4,
        sync_every: 4,
        ..Default::default()
    };
    println!("data-parallel: {} shards x {} envs, param sync every {} \
              iters", cfg.shards, cfg.n_envs, cfg.sync_every);
    let mut ms = MultiShardTrainer::new(&device, &artifact, cfg.clone())?;
    let t0 = std::time::Instant::now();
    let report_every = (iters / 6).max(1);
    for i in 0..cfg.iters {
        ms.step(i)?;
        if (i + 1) % report_every == 0 {
            println!("iter {:>4}: mean shard return {:>8.2} ({} syncs)",
                     i + 1, ms.mean_return()?, ms.sync_count);
        }
    }
    // after a sync, every shard holds identical parameters
    ms.sync_params()?;
    let params = ms.shard_params()?;
    let all_equal = params.windows(2).all(|w| w[0] == w[1]);
    println!("\nafter final sync: all {} shards share identical params: {}",
             ms.shards(), all_equal);
    println!("aggregate env steps: {} in {:.1}s",
             cfg.iters * cfg.shards * cfg.n_envs * cfg.t,
             t0.elapsed().as_secs_f64());
    Ok(())
}
