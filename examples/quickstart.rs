//! Quickstart: train CartPole-v1 with 1024 concurrent environments.
//!
//! This is the end-to-end driver for the whole stack: the coordinator
//! chains the fused roll-out+train graph over the device-resident
//! unified store and logs the reward curve.  It runs on the
//! always-available pure-Rust CPU device — the artifact is synthesized
//! in memory, no `make artifacts` needed (a `pjrt` build swaps in real
//! AOT-lowered XLA executables through the same `DeviceBackend` trait).
//!
//! Run:  cargo run --release --example quickstart
//! Env:  WARPSCI_EXAMPLE_ITERS=N   shorten the run (CI smoke uses 2)

use anyhow::Result;

use warpsci::config::RunConfig;
use warpsci::coordinator::Trainer;
use warpsci::runtime::{CpuDevice, DeviceBackend, GraphSet};
use warpsci::util::csv::human;
use warpsci::util::env_usize;

fn main() -> Result<()> {
    let iters = env_usize("WARPSCI_EXAMPLE_ITERS", 150);
    let device = CpuDevice::new();
    println!("platform: {}", device.platform());
    let artifact = device.artifact("cartpole", 1024, 32)?;
    let graphs = GraphSet::compile(&device, artifact)?;
    println!("compiled {} in {:.2?}", graphs.artifact.manifest.tag,
             graphs.compile_time);

    let cfg = RunConfig {
        env: "cartpole".into(),
        n_envs: 1024,
        t: 32,
        iters,
        seed: 0,
        metrics_every: 5,
        target_return: Some(400.0),
        log_csv: Some("results/quickstart_cartpole.csv".into()),
        ..Default::default()
    };
    let mut trainer = Trainer::new(graphs, cfg)?;
    trainer.init()?;
    println!("\n{:>6} {:>12} {:>10} {:>10} {:>12}", "iter", "return",
             "ep_len", "entropy", "steps/s");
    let t0 = std::time::Instant::now();
    for i in 0..iters {
        trainer.step_train()?;
        if (i + 1) % 5 == 0 {
            let row = trainer.record_metrics()?;
            println!("{:>6} {:>12.2} {:>10.1} {:>10.3} {:>12}",
                     row.iter as u64, row.ep_return_ema, row.ep_len_ema,
                     row.entropy,
                     human(row.env_steps / t0.elapsed().as_secs_f64()));
            if row.ep_return_ema >= 400.0 {
                println!("\nsolved: return >= 400 (CartPole-v1 optimum is \
                          500)");
                break;
            }
        }
    }
    let row = trainer.record_metrics()?;
    trainer.log.flush()?;
    println!("\nfinal return {:.1} after {} env steps in {:.1}s \
              (curve: results/quickstart_cartpole.csv)",
             row.ep_return_ema, human(row.env_steps),
             t0.elapsed().as_secs_f64());
    Ok(())
}
