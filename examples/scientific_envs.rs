//! The high-dimensional scientific scenarios: train the Lotka-Volterra
//! ecosystem manager (32-feature observations) and the 1-D
//! reaction-diffusion bioreactor controller (64-feature observations)
//! back to back on the fused CPU engine.
//!
//! Both environments were added through `envs::registry` — the engine,
//! the trainer and this example resolve them purely by name, the same
//! way `warpsci train --env ecosystem` does.
//!
//! Run:  cargo run --release --example scientific_envs
//! Env:  WARPSCI_EXAMPLE_ITERS=N   shorten the run (CI smoke uses 2)

use anyhow::Result;

use warpsci::coordinator::{Backend, CpuEngine, CpuEngineConfig};
use warpsci::envs::registry;
use warpsci::util::csv::human;
use warpsci::util::env_usize;

fn train(env: &str, iters: usize) -> Result<()> {
    let spec = registry::find(env).expect("registered env");
    println!("\n== {env}: {} ==", spec.scenario);
    println!("   obs {} x actions {} (state {} f32/lane)", spec.obs_dim,
             spec.n_actions, spec.state_dim);
    let mut eng = CpuEngine::new(CpuEngineConfig {
        threads: 0, // all cores
        seed: 0,
        ..CpuEngineConfig::new(env, 512, 16)
    })?;
    let t0 = std::time::Instant::now();
    let report_every = (iters / 5).max(1);
    for i in 0..iters {
        eng.train_iter()?;
        if (i + 1) % report_every == 0 {
            let row = eng.metrics_row(t0.elapsed().as_secs_f64())?;
            println!("   iter {:>4}  return {:>9.2}  entropy {:>6.3}  \
                      steps/s {:>10}",
                     row.iter as u64, row.ep_return_ema, row.entropy,
                     human(row.env_steps / t0.elapsed().as_secs_f64()));
        }
    }
    let row = eng.metrics_row(t0.elapsed().as_secs_f64())?;
    println!("   done: {} env steps in {:.1}s, final return {:.2}",
             human(row.env_steps), t0.elapsed().as_secs_f64(),
             row.ep_return_ema);
    Ok(())
}

fn main() -> Result<()> {
    let iters = env_usize("WARPSCI_EXAMPLE_ITERS", 60);
    train("ecosystem", iters)?;
    train("bioreactor", iters)?;
    Ok(())
}
