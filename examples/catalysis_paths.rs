//! Reaction-path discovery: H-atom actors on a potential energy surface.
//!
//! Trains both mechanisms of the paper's Fig 4 — Langmuir-Hinshelwood
//! (co-adsorbed) and Eley-Rideal (gas-phase approach) — with the *same*
//! positions-only environment encoding, which is the paper's
//! generalizability claim.  After training, replays the greedy policy on
//! the rust-side PES to print the discovered reaction path and its
//! energy profile.
//!
//! Run:  cargo run --release --example catalysis_paths
//! Env:  WARPSCI_EXAMPLE_ITERS=N   shorten the training runs

use anyhow::Result;

use warpsci::config::RunConfig;
use warpsci::coordinator::Trainer;
use warpsci::envs::catalysis::{mb_energy, Catalysis, Mechanism,
                               MIN_PRODUCT};
use warpsci::envs::CpuEnv;
use warpsci::nn::mlp::Cache;
use warpsci::policy::{Policy, PolicySpec};
use warpsci::runtime::{CpuDevice, GraphSet};
use warpsci::store::Checkpoint;
use warpsci::util::Pcg64;

fn train(device: &CpuDevice, mech: &str, iters: usize)
         -> Result<Checkpoint> {
    let artifact = device.artifact(&format!("catalysis_{mech}"), 100, 32)?;
    let graphs = GraphSet::compile(device, artifact)?;
    let cfg = RunConfig {
        env: format!("catalysis_{mech}"),
        n_envs: 100,
        t: 32,
        iters,
        seed: 1,
        metrics_every: 20,
        ..Default::default()
    };
    let mut trainer = Trainer::new(graphs, cfg)?;
    trainer.init()?;
    for i in 0..iters {
        trainer.step_train()?;
        if (i + 1) % 20 == 0 {
            let row = trainer.record_metrics()?;
            println!("  [{}] iter {:>4}: reward {:>7.2}, episode steps \
                      {:>6.1}", mech, row.iter as u64, row.ep_return_ema,
                     row.ep_len_ema);
        }
    }
    let dir = std::path::Path::new("results");
    trainer.checkpoint(dir, &format!("catalysis_{mech}"))?;
    Checkpoint::load(dir, &format!("catalysis_{mech}"))
}

/// Greedy rollout of the trained policy on the rust PES (argmax actions).
fn replay(mech: Mechanism, ck: &Checkpoint) -> Result<()> {
    // rebuild the policy net from the checkpoint parameter vector
    // (layout = models.PARAM_ORDER, enforced by the facade)
    let acts = 8usize;
    let spec = PolicySpec::new(4, 64, acts);
    let policy = Policy::from_checkpoint(ck, &spec)?;

    let mut env = Catalysis::new(mech);
    let mut prng = Pcg64::new(42);
    env.reset(&mut prng);
    env.perturb = 0.0; // canonical surface for the printed path
    let mut cache = Cache::default();
    let mut path = vec![(env.x, env.y, env.energy())];
    for _ in 0..200 {
        // a single observation row is the same bytes column-major
        let mut o = [0f32; 4];
        env.write_obs(&mut o);
        policy.forward_cols(&o, 1, &mut cache);
        let action = cache.logp[..acts]
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        let (_, done) = env.physics_step(action);
        path.push((env.x, env.y, env.energy()));
        if done {
            break;
        }
    }
    let peak = path.iter().map(|p| p.2).fold(f32::NEG_INFINITY, f32::max);
    let start_e = path[0].2;
    let end = path.last().unwrap();
    let reached = {
        let dx = end.0 - MIN_PRODUCT.0;
        let dy = end.1 - MIN_PRODUCT.1;
        (dx * dx + dy * dy).sqrt() < 0.35
    };
    println!("  greedy path: {} moves, start E {:.1} -> peak E {:.1} \
              (barrier {:.1}) -> end E {:.1}, product basin reached: {}",
             path.len() - 1, start_e, peak, peak - start_e, end.2, reached);
    // a coarse ASCII energy profile along the path
    let profile: Vec<char> = path
        .iter()
        .step_by((path.len() / 60).max(1))
        .map(|p| {
            let t = ((p.2 + 150.0) / 200.0 * 8.0).clamp(0.0, 8.0) as usize;
            [' ', '.', ':', '-', '=', '+', '*', '#', '@'][t]
        })
        .collect();
    println!("  energy profile: |{}|", profile.iter().collect::<String>());
    let _ = mb_energy(0.0, 0.0, 0.0, 0.0); // exercise the public fn
    Ok(())
}

fn main() -> Result<()> {
    let iters = warpsci::util::env_usize("WARPSCI_EXAMPLE_ITERS", 120);
    let device = CpuDevice::new();
    std::fs::create_dir_all("results").ok();
    println!("training Langmuir-Hinshelwood (co-adsorbed reactants):");
    let lh = train(&device, "lh", iters)?;
    println!("training Eley-Rideal (gas-phase approach), same encoding:");
    let er = train(&device, "er", iters)?;
    println!("\ndiscovered reaction paths (greedy policy replay):");
    println!("Langmuir-Hinshelwood:");
    replay(Mechanism::Lh, &lh)?;
    println!("Eley-Rideal:");
    replay(Mechanism::Er, &er)?;
    println!("\n(paper Fig 4: both mechanisms learned by the same \
              positions-only RL environment; reward rises while episode \
              length falls toward the reaction-path length)");
    Ok(())
}
