//! Economic policy design: train the two-level COVID-19 economy.
//!
//! 60 concurrent simulations of 51 state governors + a federal agent
//! (the paper's Fig 3 workload).  Demonstrates multi-agent training with
//! two jointly-trained policies inside one fused device-resident graph,
//! and prints the learned policy's health/economy trade-off trajectory.
//!
//! Run:  cargo run --release --example economic_policy
//! Env:  WARPSCI_EXAMPLE_ITERS=N   shorten the run

use anyhow::Result;

use warpsci::config::RunConfig;
use warpsci::coordinator::Trainer;
use warpsci::runtime::{CpuDevice, GraphSet};
use warpsci::util::csv::human;
use warpsci::util::env_usize;

fn main() -> Result<()> {
    let iters = env_usize("WARPSCI_EXAMPLE_ITERS", 200);
    let device = CpuDevice::new();
    let artifact = device.artifact("covid_econ", 60, 13)?;
    let man = artifact.manifest.clone();
    println!("two-level economy: {} envs x {} agents, {}-week horizon",
             man.n_envs, man.agents_per_env, man.max_steps);
    let graphs = GraphSet::compile(&device, artifact)?;

    let cfg = RunConfig {
        env: "covid_econ".into(),
        n_envs: 60,
        t: 13,
        iters,
        seed: 7,
        metrics_every: 10,
        log_csv: Some("results/economic_policy.csv".into()),
        ..Default::default()
    };
    let mut trainer = Trainer::new(graphs, cfg)?;
    trainer.init()?;
    println!("\n{:>6} {:>16} {:>12} {:>10} {:>12}", "iter",
             "federal return", "episodes", "entropy", "agent steps/s");
    let t0 = std::time::Instant::now();
    for i in 0..iters {
        trainer.step_train()?;
        if (i + 1) % 10 == 0 {
            let row = trainer.record_metrics()?;
            let agent_sps = row.env_steps * man.agents_per_env as f64
                / t0.elapsed().as_secs_f64();
            println!("{:>6} {:>16.3} {:>12} {:>10.3} {:>12}",
                     row.iter as u64, row.ep_return_ema,
                     row.episodes_done as u64, row.entropy,
                     human(agent_sps));
        }
    }
    let row = trainer.record_metrics()?;
    trainer.log.flush()?;
    trainer.checkpoint(std::path::Path::new("results"),
                       "economic_policy")?;
    println!("\nfinal federal episodic return: {:.3} \
              (policy checkpoint: results/economic_policy.*)",
             row.ep_return_ema);
    println!("reward trades state GDP against pandemic deaths; rising \
              return = better joint stringency/subsidy policy");
    Ok(())
}
